"""Quickstart: adaptively integrate a sharp Gaussian over [0,1]^5.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import QuadratureConfig, integrate
from repro.core.integrands import get


def main() -> None:
    # a registry integrand (the paper's f4) ...
    cfg = QuadratureConfig(d=5, integrand="f4", rel_tol=1e-6, capacity=1 << 16)
    res = integrate(cfg)
    exact = get("f4").exact(5)
    print("f4, d=5:", res.summary())
    print(f"  exact={exact:.12e}  true rel err={abs(res.integral-exact)/exact:.2e}")

    # ... and a custom integrand: any jnp-traceable f((d, N) coords) -> (N,)
    def banana(x):  # Rosenbrock-like ridge
        return jnp.exp(-5.0 * (x[1] - x[0] ** 2) ** 2 - (1.0 - x[0]) ** 2)

    cfg = QuadratureConfig(d=2, rel_tol=1e-8, capacity=1 << 13)
    res = integrate(cfg, integrand=banana)
    print("custom banana, d=2:", res.summary())


if __name__ == "__main__":
    main()
