"""End-to-end training driver: train a ~100M-param dense LM on the synthetic
pipeline with checkpointing + resume.

Defaults are CPU-sized (a ~10M model, 40 steps); pass --model 100m --steps 300
for the full run on real hardware.

Run: PYTHONPATH=src python examples/train_lm.py [--steps N] [--model 10m|100m]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, batch_for_step
from repro.models.config import ModelConfig
from repro.models.model import model_init
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train import TrainConfig, make_train_step

MODELS = {
    # ~10M params: CPU-friendly demo
    "10m": ModelConfig(
        name="demo-10m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=4096, dtype="float32",
    ),
    # ~124M params: the deliverable-scale driver (same code path)
    "100m": ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768, dtype="bfloat16",
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="10m", choices=list(MODELS))
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = MODELS[args.model]
    tcfg = TrainConfig(
        remat="none",
        opt=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = model_init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(tcfg.opt, params)
    start = 0
    if mgr.latest_step() is not None:
        restored, start = mgr.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 5 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(
                f"step {step:4d}  loss {float(metrics['ce_loss']):.4f}  "
                f"grad_norm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s"
            )
        if step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    mgr.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
