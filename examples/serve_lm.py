"""Batched serving demo: prefill a prompt batch, decode with the KV cache.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
(uses the reduced smoke config of the chosen architecture family so the
demo runs on CPU; the identical code path serves the full config on a mesh).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.serving.engine import ServeConfig, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    from repro.models.model import model_init

    params = model_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    out = generate(
        cfg,
        params,
        prompt,
        n_tokens=args.new_tokens,
        scfg=ServeConfig(temperature=args.temperature),
    )
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.new_tokens}")
    print(f"generated ids[0]: {np.asarray(out[0])}")
    print(
        f"{args.batch * args.new_tokens / dt:,.1f} tok/s "
        f"({dt:.2f}s incl. compile)"
    )


if __name__ == "__main__":
    main()
