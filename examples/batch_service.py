"""Batch quadrature service: one compiled program serving a fleet of integrals.

A parameter sweep ∫ exp(-Σ a_i²(x_i - u_i)²) dx over [0,1]³ for 24 random
(a, u) draws — the offline `integrate_batch` call and the streaming `serve`
loop, both validated against the analytic Genz-Gaussian value.

Run: PYTHONPATH=src python examples/batch_service.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import QuadratureConfig
from repro.core.integrands import get_param
from repro.service import QuadRequest, integrate_batch, serve


def main() -> None:
    family = get_param("genz_gaussian")
    d = 3
    cfg = QuadratureConfig(
        d=d,
        integrand="genz_gaussian",
        rel_tol=1e-6,
        capacity=1 << 12,
        batch_slots=8,  # 24 problems stream through 8 slots
    )
    rng = np.random.default_rng(0)
    thetas = [family.sample_theta(d, rng) for _ in range(24)]

    # offline form: results come back in submission order
    results = integrate_batch(cfg, thetas)
    worst = max(
        abs(r.integral - family.exact(d, t)) / abs(family.exact(d, t))
        for t, r in zip(thetas, results)
    )
    print(f"integrate_batch: {len(results)} problems, worst true rel err {worst:.2e}")
    for t, r in zip(thetas[:3], results[:3]):
        print(f"  a={np.array2string(t['a'], precision=2)}  {r.summary()}")
    print("  ...")

    # streaming form: results arrive in convergence order, slots are refilled
    # mid-flight (continuous batching) — watch admitted_at/finished_at
    reqs = (QuadRequest(req_id=i, theta=t) for i, t in enumerate(thetas))
    for res in serve(cfg, reqs, family):
        print(
            f"serve: req {res.req_id:2d} admitted@{res.admitted_at:3d} "
            f"finished@{res.finished_at:3d} [{res.status}] I={res.integral:.9e}"
        )


if __name__ == "__main__":
    main()
