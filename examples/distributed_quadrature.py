"""Distributed adaptive quadrature with round-robin load redistribution.

Re-executes itself with 8 forced host devices (the same code runs on a real
multi-chip mesh unchanged), integrates a discontinuous integrand whose work
concentrates on a few ranks, and prints the per-device balance with
redistribution ON vs OFF.

Run: PYTHONPATH=src python examples/distributed_quadrature.py
"""

import os
import subprocess
import sys


def main_worker() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core.config import QuadratureConfig
    from repro.core.distributed import integrate_distributed
    from repro.core.integrands import get

    print(f"devices: {len(jax.devices())}")
    base = dict(d=4, integrand="f6", rel_tol=1e-6, capacity=1 << 13, max_iters=200)
    for redis in ("ring", "off"):
        cfg = QuadratureConfig(redistribution=redis, **base)
        res = integrate_distributed(cfg)
        exact = get("f6").exact(4)
        share = res.evals_per_device / max(res.n_evals, 1)
        print(
            f"redistribution={redis:3}: {res.summary()}\n"
            f"   true rel err {abs(res.integral-exact)/exact:.2e}; "
            f"mean work imbalance {res.mean_imbalance():.3f}; "
            f"per-device eval share {np.array2string(share, precision=3)}"
        )


if __name__ == "__main__":
    if os.environ.get("_REPRO_DIST_WORKER") == "1":
        main_worker()
    else:
        env = dict(os.environ)
        env["_REPRO_DIST_WORKER"] = "1"
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.exit(subprocess.call([sys.executable, __file__], env=env))
