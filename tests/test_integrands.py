"""Analytic exact values + basic sanity of the paper's benchmark integrands."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integrands


@pytest.mark.parametrize("name", sorted(integrands.REGISTRY))
@pytest.mark.parametrize("d", [1, 2, 3])
def test_exact_matches_bruteforce_grid(name, d):
    """Cross-check the analytic exact value with a dense midpoint grid.

    The midpoint rule converges O(n^-2) for smooth f; we only need a loose
    agreement to catch wrong formulas (sign errors, off-by-one in indices).
    """
    spec = integrands.get(name)
    n = {1: 40001, 2: 801, 3: 151}[d]
    axes = [np.linspace(0.5 / n, 1 - 0.5 / n, n)] * d
    grid = np.stack([g.ravel() for g in np.meshgrid(*axes, indexing="ij")])
    vals = np.asarray(spec.fn(jnp.asarray(grid)))
    approx = vals.mean()  # midpoint rule on [0,1]^d
    exact = spec.exact(d)
    # discontinuous/peaked integrands converge slower on a uniform grid
    rtol = {"f2": 5e-2, "f4": 5e-2, "f6": 5e-2}.get(name, 5e-3)
    assert approx == pytest.approx(exact, rel=rtol), (name, d, approx, exact)


def test_f6_cutoff_structure():
    # d=2: any coordinate above its cutoff zeroes the integrand
    f = integrands.get("f6").fn
    x_in = jnp.asarray([[0.3], [0.4]])  # cutoffs: 0.4, 0.5
    x_out = jnp.asarray([[0.45], [0.4]])
    assert float(f(x_in)[0]) > 0.0
    assert float(f(x_out)[0]) == 0.0


def test_f7_exact_small_d():
    # d=1: integral of x^22 = 1/23
    assert integrands.get("f7").exact(1) == pytest.approx(1.0 / 23.0, rel=1e-12)


def test_f1_exact_d1():
    # d=1: integral of cos(x) over [0,1] = sin(1)
    assert integrands.get("f1").exact(1) == pytest.approx(np.sin(1.0), rel=1e-12)
