"""Unit tests for the static ring-shift redistribution schedule."""

import pytest

from repro.core.redistribution import make_schedule


def _is_pow2(x: int) -> bool:
    return x > 0 and x & (x - 1) == 0


@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 6, 7, 8, 12, 16, 100, 1000])
def test_schedule_shifts_unique_and_in_range(n):
    sched = make_schedule(n)
    assert len(set(sched)) == len(sched), "duplicate shift"
    for s in sched:
        assert 1 <= s < n, f"shift {s} out of ring range for {n} devices"


def test_single_device_has_empty_schedule():
    assert make_schedule(1) == ()
    assert make_schedule(0) == ()


def test_two_and_three_devices():
    assert make_schedule(2) == (1,)
    assert make_schedule(3) == (1, 2)


def test_powers_of_two_come_first():
    """ICI-torus-friendly ordering: every power-of-two stride < n precedes
    every non-power-of-two stride (within the max_len budget)."""
    for n in (4, 6, 8, 12, 16, 32, 100):
        sched = make_schedule(n)
        seen_non_pow2 = False
        for s in sched:
            if _is_pow2(s):
                assert not seen_non_pow2, f"pow2 shift {s} after non-pow2 in {sched}"
            else:
                seen_non_pow2 = True
        # the pow2 prefix is complete: all powers of two below n (up to the
        # length cap) are present
        pow2_in = [s for s in sched if _is_pow2(s)]
        expected = []
        s = 1
        while s < n and len(expected) < 8:
            expected.append(s)
            s <<= 1
        assert pow2_in == expected


@pytest.mark.parametrize("n", [2, 3, 4, 7, 8, 9])
def test_small_rings_cover_every_distance(n):
    """With few devices the schedule should reach every ring distance, so
    any imbalance pattern is eventually smoothed."""
    sched = make_schedule(n)
    assert set(sched) == set(range(1, n))


def test_max_len_caps_schedule():
    for n in (1 << 10, 1 << 13):
        sched = make_schedule(n)
        assert len(sched) == 8  # default max_len
        assert make_schedule(n, max_len=4) == sched[:4]


def test_huge_ring_beyond_pow2_budget():
    """n > 2^max_len: the schedule is all powers of two (the budget is spent
    before any odd stride fits)."""
    sched = make_schedule(1 << 12, max_len=8)
    assert sched == (1, 2, 4, 8, 16, 32, 64, 128)


def test_non_power_of_two_fill():
    # 6 devices: pow2 strides 1,2,4 then odd strides 3,5
    assert make_schedule(6) == (1, 2, 4, 3, 5)
