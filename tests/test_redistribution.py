"""Unit tests for the static ring-shift redistribution schedule, plus
property tests of the transfer-round invariants on a real (virtual) mesh."""

import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.redistribution import make_schedule


def _is_pow2(x: int) -> bool:
    return x > 0 and x & (x - 1) == 0


@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 6, 7, 8, 12, 16, 100, 1000])
def test_schedule_shifts_unique_and_in_range(n):
    sched = make_schedule(n)
    assert len(set(sched)) == len(sched), "duplicate shift"
    for s in sched:
        assert 1 <= s < n, f"shift {s} out of ring range for {n} devices"


def test_single_device_has_empty_schedule():
    assert make_schedule(1) == ()
    assert make_schedule(0) == ()


def test_two_and_three_devices():
    assert make_schedule(2) == (1,)
    assert make_schedule(3) == (1, 2)


def test_powers_of_two_come_first():
    """ICI-torus-friendly ordering: every power-of-two stride < n precedes
    every non-power-of-two stride (within the max_len budget)."""
    for n in (4, 6, 8, 12, 16, 32, 100):
        sched = make_schedule(n)
        seen_non_pow2 = False
        for s in sched:
            if _is_pow2(s):
                assert not seen_non_pow2, f"pow2 shift {s} after non-pow2 in {sched}"
            else:
                seen_non_pow2 = True
        # the pow2 prefix is complete: all powers of two below n (up to the
        # length cap) are present
        pow2_in = [s for s in sched if _is_pow2(s)]
        expected = []
        s = 1
        while s < n and len(expected) < 8:
            expected.append(s)
            s <<= 1
        assert pow2_in == expected


@pytest.mark.parametrize("n", [2, 3, 4, 7, 8, 9])
def test_small_rings_cover_every_distance(n):
    """With few devices the schedule should reach every ring distance, so
    any imbalance pattern is eventually smoothed."""
    sched = make_schedule(n)
    assert set(sched) == set(range(1, n))


def test_max_len_caps_schedule():
    for n in (1 << 10, 1 << 13):
        sched = make_schedule(n)
        assert len(sched) == 8  # default max_len
        assert make_schedule(n, max_len=4) == sched[:4]


def test_huge_ring_beyond_pow2_budget():
    """n > 2^max_len: the schedule is all powers of two (the budget is spent
    before any odd stride fits)."""
    sched = make_schedule(1 << 12, max_len=8)
    assert sched == (1, 2, 4, 8, 16, 32, 64, 128)


def test_non_power_of_two_fill():
    # 6 devices: pow2 strides 1,2,4 then odd strides 3,5
    assert make_schedule(6) == (1, 2, 4, 3, 5)


# --- transfer-round invariants (needs a multi-device mesh) --------------------
#
# Hypothesis drives random per-device populations through one redistribute
# round inside shard_map and checks the structural invariants the adaptive
# drivers rely on: conservation of the live-region population (count and
# coordinate multiset — transfers move coordinates, never duplicate or drop
# them), contiguity of the occupied block on both donor and receiver, and
# re-evaluation marking of everything that moved.

_N_DEV = len(jax.devices())
_needs_mesh = pytest.mark.skipif(
    _N_DEV < 2, reason="redistribute is an inter-device transfer; needs >= 2 devices"
)

_C = 64  # store capacity per device (small: compile once, run many examples)
_D = 2
_CAP = 8  # message cap per round
_LIMIT = 3 * _C // 4


def _stacked_state(n_dev, counts, it, seed):
    from repro.core.region_store import RegionState

    rng = np.random.default_rng(seed)
    z = np.zeros
    centers = rng.uniform(0.1, 0.9, (n_dev, _C, _D))
    halfw = rng.uniform(0.01, 0.1, (n_dev, _C, _D))
    est = rng.uniform(-1.0, 1.0, (n_dev, _C))
    err = rng.uniform(1e-6, 1.0, (n_dev, _C))
    active = z((n_dev, _C), bool)
    for dev, cnt in enumerate(counts):
        active[dev, :cnt] = True
    return RegionState(
        centers=jnp.asarray(centers),
        halfw=jnp.asarray(halfw),
        est=jnp.where(jnp.asarray(active), jnp.asarray(est), 0.0),
        err=jnp.where(jnp.asarray(active), jnp.asarray(err), 0.0),
        axis=jnp.zeros((n_dev, _C), jnp.int32),
        active=jnp.asarray(active),
        fresh=jnp.zeros((n_dev, _C), bool),
        fin_integral=jnp.zeros((n_dev,)),
        fin_error=jnp.zeros((n_dev,)),
        n_evals=jnp.zeros((n_dev,)),
        it=jnp.full((n_dev,), it, jnp.int32),
        overflowed=jnp.zeros((n_dev,), bool),
    )


_ROUND_CACHE: dict = {}


def _run_round(state, n_dev):
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import _shard_map
    from repro.core.redistribution import redistribute

    fn = _ROUND_CACHE.get(n_dev)
    if fn is None:
        mesh = jax.make_mesh((n_dev,), ("dev",), devices=jax.devices()[:n_dev])
        schedule = make_schedule(n_dev)

        def body(state):
            state = jax.tree.map(lambda x: x[0], state)
            state = redistribute(
                state,
                axis_name="dev",
                n_devices=n_dev,
                schedule=schedule,
                cap=_CAP,
                limit=_LIMIT,
            )
            return jax.tree.map(lambda x: x[None], state)

        fn = jax.jit(_shard_map(body, mesh=mesh, in_specs=P("dev"), out_specs=P("dev")))
        _ROUND_CACHE[n_dev] = fn
    return fn(state)


def _coord_multiset(state):
    c = np.asarray(state.centers)
    h = np.asarray(state.halfw)
    act = np.asarray(state.active)
    rows = np.concatenate([c, h], axis=-1)[act]  # exact float64 copies
    return sorted(map(tuple, rows))


@_needs_mesh
def test_transfer_round_invariants_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        n_dev=st.sampled_from(sorted({2, min(4, _N_DEV), _N_DEV})),
        counts_seed=st.integers(0, 2**31 - 1),
        it=st.integers(0, 12),
    )
    @settings(max_examples=15, deadline=None)
    def check(n_dev, counts_seed, it):
        rng = np.random.default_rng(counts_seed)
        counts = rng.integers(0, _LIMIT + 1, n_dev).tolist()
        state = _stacked_state(n_dev, counts, it, counts_seed)
        before = _coord_multiset(state)
        out = _run_round(state, n_dev)

        act = np.asarray(out.active)
        fresh = np.asarray(out.fresh)
        err = np.asarray(out.err)
        new_counts = act.sum(axis=1)
        # conservation: live-region count and coordinate multiset
        assert int(new_counts.sum()) == sum(counts)
        assert _coord_multiset(out) == before
        for dev in range(n_dev):
            n = int(new_counts[dev])
            # occupied block stays contiguous on donor and receiver alike
            assert not act[dev, n:].any(), (dev, counts, new_counts)
            # a receiver never exceeds the transfer limit
            if n > counts[dev]:
                assert n <= _LIMIT, (dev, counts, new_counts)
                # every spliced-in region is marked for re-evaluation with
                # invalidated estimates (conservative in-flight accounting)
                moved = fresh[dev] & act[dev]
                assert moved.sum() == n - counts[dev]
                assert not err[dev][moved].any()
            # donors / bystanders keep their surviving prefix untouched
            keep = min(n, counts[dev])
            np.testing.assert_array_equal(
                np.asarray(out.est)[dev, :keep],
                np.asarray(state.est)[dev, :keep],
            )

    check()


@_needs_mesh
def test_transfer_round_moves_from_overloaded_to_idle():
    """Deterministic smoke: with all work on rank 0, one round transfers a
    full fair-share-capped payload to its shift-1 ring neighbour."""
    counts = [40] + [0] * (_N_DEV - 1)
    # the donor may not send below its fair ceiling, the receiver not pull
    # above its fair floor, and the message cap bounds everything
    expected = min(_CAP, 40 - (-(-40 // _N_DEV)), 40 // _N_DEV)
    state = _stacked_state(_N_DEV, counts, it=0, seed=7)  # shift = schedule[0] = 1
    out = _run_round(state, _N_DEV)
    new_counts = np.asarray(out.active).sum(axis=1)
    assert int(new_counts.sum()) == 40
    assert new_counts[0] == 40 - expected
    assert new_counts[1] == expected  # ring neighbour at shift 1
    # the donor sheds its tail window [n - sent, n): the paper's "largest
    # error subregions, chosen after sorting"
    sent = np.concatenate(
        [np.asarray(state.centers)[0], np.asarray(state.halfw)[0]], axis=-1
    )[40 - expected : 40]
    got = np.concatenate(
        [np.asarray(out.centers)[1], np.asarray(out.halfw)[1]], axis=-1
    )[:expected]
    assert sorted(map(tuple, sent)) == sorted(map(tuple, got))
