import jax

# The quadrature stack targets float64 accuracy experiments (the paper runs
# down to tau_rel = 1e-12); LM-substrate code always passes explicit dtypes,
# so enabling x64 here does not affect those tests.
jax.config.update("jax_enable_x64", True)
