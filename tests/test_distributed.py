"""Distributed quadrature: multi-device correctness + load-balancing checks.

Runs ``repro.core.dist_selftest`` in a subprocess so that
``--xla_force_host_platform_device_count`` can take effect (the main pytest
process has already initialised jax with a single device).
"""

import json
import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def selftest_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.dist_selftest", "8"],
        capture_output=True,
        text=True,
        timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    assert line, proc.stdout[-4000:]
    return json.loads(line[-1][len("RESULT_JSON:") :])


def test_selftest_ran_on_8_devices(selftest_output):
    assert selftest_output["n_devices"] == 8


def test_distributed_converges_and_is_accurate(selftest_output):
    for case in selftest_output["cases"]:
        dist = case["dist"]
        assert dist["status"] == "converged", case
        ach = abs(dist["I"] - case["exact"]) / abs(case["exact"])
        assert ach <= 10 * case["rel_tol"], (case["integrand"], ach)


def test_distributed_matches_single_device(selftest_output):
    for case in selftest_output["cases"]:
        # both drivers meet the same tolerance -> they must agree to ~2*tol
        rel = abs(case["dist"]["I"] - case["single"]["I"]) / abs(case["exact"])
        assert rel <= 4 * case["rel_tol"], case


def test_work_is_distributed(selftest_output):
    # every device must perform a nontrivial share of the evaluations
    for case in selftest_output["cases"]:
        per_dev = case["dist"]["evals_per_device"]
        total = sum(per_dev)
        assert total > 0
        assert min(per_dev) > 0.01 * total / len(per_dev), (
            case["integrand"],
            per_dev,
        )


def test_redistribution_improves_balance(selftest_output):
    # averaged over the suite, round-robin redistribution must not worsen the
    # per-iteration work imbalance vs the naive static decomposition
    imb_on = [c["dist"]["mean_imbalance"] for c in selftest_output["cases"]]
    imb_off = [c["dist_noredist"]["mean_imbalance"] for c in selftest_output["cases"]]
    assert sum(imb_on) <= sum(imb_off) + 0.05, (imb_on, imb_off)
