"""SSD chunked scan vs the naive O(L) recurrence oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.mamba2 import _ssd_chunked, mamba_decode, mamba_forward, mamba_init, mamba_cache_init


def _cfg(chunk=16):
    return ModelConfig(
        name="ssd-test",
        family="ssm",
        n_layers=1,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=64,
        ssm_state=16,
        ssm_expand=2,
        ssm_headdim=32,
        ssm_chunk=chunk,
        dtype="float32",
    )


def _naive_recurrence(x, dt, b_mat, c_mat, a):
    """Oracle: step-by-step linear recurrence h_t = exp(a_t) h_{t-1} + dt_t B_t x_t."""
    B, L, H, P = x.shape
    N = b_mat.shape[-1]
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        ga = np.exp(a[:, t])  # (B,H)
        h = h * ga[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t][:, :, None], b_mat[:, t]
        )
        ys.append(np.einsum("bhpn,bn->bhp", h, c_mat[:, t]))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_matches_naive_recurrence(chunk):
    cfg = _cfg(chunk)
    rng = np.random.default_rng(0)
    B, L, H, P, N = 2, 64, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    x = rng.standard_normal((B, L, H, P))
    dt = rng.uniform(0.1, 0.9, (B, L, H))
    a = -rng.uniform(0.05, 1.0, (B, L, H))
    b_mat = rng.standard_normal((B, L, N))
    c_mat = rng.standard_normal((B, L, N))

    y, final = _ssd_chunked(
        cfg,
        jnp.asarray(x, jnp.float32),
        jnp.asarray(dt, jnp.float32),
        jnp.asarray(b_mat, jnp.float32),
        jnp.asarray(c_mat, jnp.float32),
        jnp.asarray(a, jnp.float32),
    )
    y_ref, h_ref = _naive_recurrence(x, dt, b_mat, c_mat, a)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=2e-4, atol=2e-4)


def test_initial_state_continuation():
    """SSD over [first half] then [second half with carried state] == full run."""
    cfg = _cfg(16)
    rng = np.random.default_rng(1)
    B, L, H, P, N = 1, 64, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    x, b_mat, c_mat = mk(B, L, H, P), mk(B, L, N), mk(B, L, N)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.05, 1.0, (B, L, H)), jnp.float32)

    y_full, h_full = _ssd_chunked(cfg, x, dt, b_mat, c_mat, a)
    h = L // 2
    y1, s1 = _ssd_chunked(cfg, x[:, :h], dt[:, :h], b_mat[:, :h], c_mat[:, :h], a[:, :h])
    y2, s2 = _ssd_chunked(
        cfg, x[:, h:], dt[:, h:], b_mat[:, h:], c_mat[:, h:], a[:, h:], initial_state=s1
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(h_full), rtol=1e-4, atol=1e-4)
