"""Golden-value regression tests: every ParamIntegrand family against its
analytic ``exact()``, across dimensions and both classifiers.

Thetas are drawn deterministically (seeded per dimension), so these pin the
full solver stack — rule evaluation, classification, split/compact, window
ladder — to analytic ground truth at fixed tolerances.  A refactor that
perturbs any refinement decision shows up here as a drift in achieved
accuracy or a status change.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from repro.core import QuadratureConfig, integrate
from repro.core.integrands import PARAM_REGISTRY, bind, get_param

# rel_tol / capacity per dimension: d=5 needs a looser target — at 1e-5 the
# Genz families saturate an 8k store (status "capacity") before converging
_BY_D = {2: (1e-6, 1 << 11), 3: (1e-6, 1 << 11), 5: (1e-4, 1 << 13)}


def _theta(family, d):
    return family.sample_theta(d, np.random.default_rng(100 + d))


@pytest.mark.parametrize("classifier", ["robust", "aggressive"])
@pytest.mark.parametrize("d", sorted(_BY_D))
@pytest.mark.parametrize("name", sorted(PARAM_REGISTRY))
def test_family_converges_to_exact(name, d, classifier):
    family = get_param(name)
    theta = _theta(family, d)
    rel_tol, capacity = _BY_D[d]
    cfg = QuadratureConfig(
        d=d,
        rel_tol=rel_tol,
        capacity=capacity,
        max_iters=200,
        classifier=classifier,
    )
    res = integrate(cfg, bind(family, theta).fn)
    exact = family.exact(d, theta)
    assert res.status == "converged", (name, d, classifier, res.summary())
    # claimed error bound is honest: true error within 2x the requested
    # relative tolerance (observed headroom is ~5-100x, see the pinned
    # margins in the PR that introduced this file)
    rel_err = abs(res.integral - exact) / max(abs(exact), 1e-300)
    assert rel_err <= 2 * rel_tol, (name, d, classifier, rel_err, rel_tol)
    # the reported error estimate itself satisfied the requested budget
    assert res.error <= max(cfg.abs_tol, abs(res.integral) * rel_tol)


def test_exact_values_are_finite_and_stable():
    """The analytic references themselves: deterministic, finite, nonzero."""
    for name, family in PARAM_REGISTRY.items():
        for d in sorted(_BY_D):
            theta = _theta(family, d)
            a = family.exact(d, theta)
            b = family.exact(d, theta)
            assert a == b, name
            assert np.isfinite(a) and a != 0.0, (name, d, a)
