"""Performance observatory: machine profiler, kernel catalog, regression gate.

Everything runs at toy sizes — tiny probe overrides for the machine file,
the smallest rungs for the catalog — so the suite exercises the real
lower/compile/cost/measure path without benchmark-scale wall time.  The
numbers themselves are not asserted (this is a shared CI box); the
*structure* is: positive FLOPs and wall times, all four kernels present,
the regression gate's exit-code contract, and the v5e preset pinned to the
constants ``benchmarks/roofline.py`` documents as its fallback.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.perf import PRESETS, load_machine, profile_machine, save_machine
from repro.perf import catalog as catalog_lib
from repro.perf import regress
from repro.perf import report as report_lib

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- machine profiler --------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_machine():
    # toy probe sizes: the path is real, the wall time is milliseconds
    return profile_machine(fast=True, matmul_n=64, stream_n=1 << 12, reps=1)


def test_profile_machine_shape(tiny_machine):
    m = tiny_machine
    assert m["source"] == "measured"
    assert m["peak_flops"] > 0 and m["mem_bw"] > 0 and m["reduce_bw"] > 0
    assert m["ici_bw"] is None  # single-device pytest process
    assert m["meta"]["platform"] == "cpu"
    assert set(m["probes"]) == {
        "matmul_f64",
        "matmul_f32",
        "saxpy",
        "reduction",
        "ici_ppermute",
    }


def test_machine_save_load_round_trip(tiny_machine, tmp_path):
    path = str(tmp_path / "machine.json")
    save_machine(tiny_machine, path)
    loaded = load_machine(path)
    assert loaded == json.loads(json.dumps(tiny_machine))  # float-exact via json


def test_load_machine_rejects_non_machine_file(tmp_path):
    path = str(tmp_path / "bogus.json")
    with open(path, "w") as f:
        json.dump({"metrics": {}}, f)
    with pytest.raises(ValueError, match="not a machine file"):
        load_machine(path)


def test_resolve_machine_explicit_path_must_exist(tmp_path):
    from repro.perf import resolve_machine

    with pytest.raises(FileNotFoundError):
        resolve_machine(str(tmp_path / "nope.json"))


def test_v5e_preset_pinned_to_roofline_constants():
    """The documented fallback can never drift from the retired constants."""
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import roofline
    finally:
        sys.path.pop(0)
    v5e = PRESETS["v5e"]
    assert v5e["peak_flops"] == roofline.PEAK_FLOPS
    assert v5e["mem_bw"] == roofline.HBM_BW
    assert v5e["ici_bw"] == roofline.ICI_BW


# --- kernel cost catalog -----------------------------------------------------


@pytest.fixture(scope="module")
def tiny_catalog(tiny_machine):
    from repro.core.config import QuadratureConfig

    # far smaller than default_configs(fast=True): the suite exercises the
    # lower/cost/measure path, not benchmark-scale shapes
    cub = QuadratureConfig(d=3, integrand="f4", capacity=1 << 8).validate()
    veg = QuadratureConfig(
        d=4, integrand="f4", backend="vegas", mc_samples=2048, mc_shards=8
    ).validate()
    svc = QuadratureConfig(
        d=2,
        integrand="genz_gaussian",
        capacity=1 << 8,
        batch_slots=4,
        sync_every=4,
    ).validate()
    cfgs = {
        "gm_eval": cub,
        "advance": cub,
        "vegas_iterate": veg,
        "service_dispatch": svc,
    }
    return catalog_lib.build_catalog(tiny_machine, fast=True, reps=1, configs=cfgs)


def test_catalog_covers_required_kernels(tiny_catalog):
    kernels = {e["kernel"] for e in tiny_catalog["entries"]}
    # the acceptance set: GM eval, VEGAS iterate, fused service dispatch
    assert {"gm_eval", "vegas_iterate", "service_dispatch"} <= kernels
    assert kernels <= set(catalog_lib.KERNELS)


def test_catalog_entries_are_roofline_complete(tiny_catalog):
    for e in tiny_catalog["entries"]:
        assert e["flops"] > 0, e["kernel"]
        assert e["bytes"] > 0, e["kernel"]
        assert e["measured_s"] > 0, e["kernel"]
        assert e["predicted_s"] > 0, e["kernel"]
        assert e["roofline_frac"] == pytest.approx(
            e["predicted_s"] / e["measured_s"]
        )
        assert e["dominant"] in ("compute", "memory")
        assert e["scan_trips"] >= 1


def test_catalog_scales_dispatch_by_scan_trips(tiny_catalog):
    disp = [e for e in tiny_catalog["entries"] if e["kernel"] == "service_dispatch"]
    assert disp, "fused dispatch missing from catalog"
    for e in disp:
        # HloCostAnalysis counts the scan body once; the catalog multiplies
        # by the known trip count (sync_every)
        assert e["scan_trips"] > 1
        assert e["flops_total"] == pytest.approx(e["flops"] * e["scan_trips"])
        assert e["bytes_total"] == pytest.approx(e["bytes"] * e["scan_trips"])


def test_catalog_round_trip_and_table(tiny_catalog, tmp_path):
    path = str(tmp_path / "catalog.json")
    catalog_lib.save_catalog(tiny_catalog, path)
    loaded = catalog_lib.load_catalog(path)
    assert loaded["entries"] == json.loads(json.dumps(tiny_catalog["entries"]))
    table = catalog_lib.render_table(loaded["entries"])
    assert "roofline frac" in table
    for k in ("gm_eval", "vegas_iterate", "service_dispatch"):
        assert k in table


# --- regression gate ---------------------------------------------------------


def _summary(metrics, **meta):
    base_meta = {
        "date": "2026-08-08T00:00:00",
        "git_sha": "deadbee",
        "jax_version": "0.4.37",
        "platform": "cpu",
        "device_kind": "cpu",
        "device_count": 1,
    }
    base_meta.update(meta)
    return {"meta": base_meta, "metrics": metrics}


def _write(tmp_path, name, payload):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump(payload, f)
    return path


def test_regress_identical_exits_zero(tmp_path):
    base = _write(tmp_path, "base.json", _summary({"a": 100.0, "b": 5.0}))
    assert regress.main([base, base]) == 0


def test_regress_fails_on_1p5x_slowdown(tmp_path):
    base = _write(tmp_path, "base.json", _summary({"a": 100.0, "b": 5.0}))
    cand = _write(tmp_path, "cand.json", _summary({"a": 150.0, "b": 5.0}))
    assert regress.main([base, cand]) == 1


def test_regress_warn_zone_exits_zero(tmp_path):
    # 1.2x: above warn (1.1) but below fail (1.3) — warns, still passes
    base = _write(tmp_path, "base.json", _summary({"a": 100.0}))
    cand = _write(tmp_path, "cand.json", _summary({"a": 120.0}))
    assert regress.main([base, cand]) == 0
    rows, _ = regress.compare(_summary({"a": 100.0}), _summary({"a": 120.0}))
    assert rows[0]["verdict"] == "warn"


def test_regress_relaxed_thresholds(tmp_path):
    # the CI cross-machine mode: 1.5x passes under --fail-ratio 10
    base = _write(tmp_path, "base.json", _summary({"a": 100.0}))
    cand = _write(tmp_path, "cand.json", _summary({"a": 150.0}))
    assert regress.main([base, cand, "--fail-ratio", "10", "--warn-ratio", "3"]) == 0


def test_regress_platform_mismatch_rejected(tmp_path):
    base = _write(tmp_path, "base.json", _summary({"a": 1.0}, platform="tpu"))
    cand = _write(tmp_path, "cand.json", _summary({"a": 1.0}, platform="cpu"))
    assert regress.main([base, cand]) == 2
    with pytest.raises(regress.RegressError, match="platform mismatch"):
        regress.check_compatible(
            _summary({}, platform="tpu"), _summary({}, platform="cpu")
        )
    # the override downgrades the rejection to a comparison
    assert regress.main([base, cand, "--allow-platform-mismatch"]) == 0


def test_regress_coverage_changes_warn_not_fail():
    rows, warnings = regress.compare(
        _summary({"kept": 1.0, "dropped": 1.0}),
        _summary({"kept": 1.0, "added": 1.0}),
    )
    assert [r["metric"] for r in rows] == ["kept"]
    assert any("dropped" in w for w in warnings)
    assert any("added" in w for w in warnings)


def test_regress_rejects_non_summary_file(tmp_path):
    bogus = _write(tmp_path, "bogus.json", {"records": []})
    with pytest.raises(regress.RegressError, match="not a BENCH_summary"):
        regress.load_summary(bogus)


# --- bench summary + provenance meta -----------------------------------------


def test_save_results_meta_round_trip(tmp_path, monkeypatch):
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import _common
    finally:
        sys.path.pop(0)
    monkeypatch.setattr(_common, "_REPO", str(tmp_path))
    monkeypatch.setattr(_common, "RUN_DATE", "2026-08-08T00:00:00")
    path = _common.save_results("unit", [{"x": 1}], meta={"extra": "y"})
    with open(path) as f:
        data = json.load(f)
    assert data["records"] == [{"x": 1}]
    meta = data["meta"]
    assert meta["date"] == "2026-08-08T00:00:00"
    assert meta["extra"] == "y"
    # provenance fields the regression gate keys off
    assert meta["platform"] == "cpu" and meta["device_count"] == 1
    assert meta["jax_version"] is not None


def test_save_bench_summary_is_valid_regress_input(tmp_path, monkeypatch):
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import _common
    finally:
        sys.path.pop(0)
    monkeypatch.setattr(_common, "_REPO", str(tmp_path))
    path = _common.save_bench_summary({"m1": 10.0, "m2": 20})
    summary = regress.load_summary(path)  # schema-validates
    assert summary["metrics"] == {"m1": 10.0, "m2": 20.0}
    assert regress.main([path, path]) == 0


def test_bench_runner_worker_path_unaffected():
    """The committed BENCH_summary baseline must stay a valid gate input."""
    path = os.path.join(_REPO, "results", "benchmarks", "BENCH_summary.json")
    summary = regress.load_summary(path)
    assert summary["metrics"], "committed baseline has no metrics"
    assert summary["meta"]["platform"] == "cpu"


# --- report ------------------------------------------------------------------


def test_report_renders_all_sections(tiny_machine, tiny_catalog, tmp_path):
    bench_dir = str(tmp_path / "benchmarks")
    os.makedirs(bench_dir)
    with open(os.path.join(bench_dir, "BENCH_summary.json"), "w") as f:
        json.dump(_summary({"eval_window/x": 100.0}), f)
    md = report_lib.render_markdown(tiny_machine, tiny_catalog, bench_dir, None)
    for kernel in ("gm_eval", "vegas_iterate", "service_dispatch"):
        assert kernel in md
    assert "roofline frac" in md
    assert "eval_window/x" in md
    assert "## Machine" in md and "## Benchmark trajectory" in md
    html = report_lib.render_html(md)
    assert "gm_eval" in html


def test_report_includes_latency_and_idle_from_metrics(
    tiny_machine, tiny_catalog, tmp_path
):
    import numpy as np

    from repro.core.config import QuadratureConfig
    from repro.core.integrands import get_param
    from repro.service import BatchScheduler, QuadRequest
    from repro.telemetry import JsonlSink, Recorder

    family = get_param("genz_gaussian")
    cfg = QuadratureConfig(
        d=2,
        integrand="genz_gaussian",
        rel_tol=1e-4,
        capacity=1 << 9,
        batch_slots=4,
        max_iters=60,
        sync_every=4,
    )
    metrics_path = str(tmp_path / "m.jsonl")
    rec = Recorder(sinks=(JsonlSink(metrics_path),))
    rng = np.random.default_rng(0)
    reqs = [QuadRequest(req_id=i, theta=family.sample_theta(2, rng)) for i in range(5)]
    list(BatchScheduler(cfg, family, recorder=rec).serve(reqs))
    rec.close()

    md = report_lib.render_markdown(
        tiny_machine, tiny_catalog, str(tmp_path / "nobench"), metrics_path
    )
    assert "service.dispatch_wall_s" in md
    assert "idle fraction" in md
    # a real latency table rendered (not the all-dashes empty row)
    dispatch_row = next(
        l for l in md.splitlines() if l.startswith("| service.dispatch_wall_s")
    )
    assert "ms" in dispatch_row


def test_report_cli_writes_both_files(tiny_machine, tiny_catalog, tmp_path):
    machine_path = str(tmp_path / "machine.json")
    catalog_path = str(tmp_path / "catalog.json")
    save_machine(tiny_machine, machine_path)
    catalog_lib.save_catalog(tiny_catalog, catalog_path)
    out = str(tmp_path / "out")
    rc = report_lib.main(
        [
            "--machine",
            machine_path,
            "--catalog",
            catalog_path,
            "--bench-dir",
            str(tmp_path / "nobench"),
            "--out",
            out,
        ]
    )
    assert rc == 0
    assert os.path.exists(os.path.join(out, "PERF_REPORT.md"))
    assert os.path.exists(os.path.join(out, "PERF_REPORT.html"))


# --- CLI smoke (subprocess: the documented invocations actually run) ---------


def test_regress_cli_subprocess(tmp_path):
    base = _write(tmp_path, "base.json", _summary({"a": 100.0}))
    cand = _write(tmp_path, "cand.json", _summary({"a": 150.0}))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.perf.regress", base, cand],
        capture_output=True,
        text=True,
        timeout=120,
        cwd=_REPO,
        env=env,
    )
    assert proc.returncode == 1
    assert "FAIL" in proc.stdout
