"""VEGAS backend (repro.mc): unit, determinism, parity and statistics.

Statistical correctness asserts ``|estimate - exact| < 5 sigma`` of the
*reported* error for all three ParamIntegrand families at d ∈ {5, 10} —
a sound estimator with covering error bars fails this with probability
< 1e-6 per case at fixed seed.  Single-vs-multi-device bit parity runs the
``repro.mc.multi_device`` selftest in a subprocess (same idiom as the
distributed cubature tests) so virtual devices can be forced.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import QuadratureConfig
from repro.core.integrands import PARAM_REGISTRY, get as get_integrand
from repro.mc import grid as grid_lib, stratified
from repro.mc.engine import init_state, integrate_vegas, make_iterate

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- grid ---------------------------------------------------------------------


def test_uniform_grid_is_identity_map():
    edges = grid_lib.uniform_edges(3, 16)
    y = jax.random.uniform(jax.random.PRNGKey(0), (3, 100), jnp.float64)
    x01, jac = grid_lib.apply_map(edges, y)
    np.testing.assert_allclose(np.asarray(x01), np.asarray(y), atol=1e-14)
    np.testing.assert_allclose(np.asarray(jac), 1.0, atol=1e-12)


def test_refine_keeps_edges_valid_and_concentrates():
    nb = 32
    edges = grid_lib.uniform_edges(2, nb)
    # all observed mass in the first quarter of axis 0; axis 1 flat
    dsum = np.ones((2, nb))
    dsum[0] = 1e-12
    dsum[0, : nb // 4] = 1.0
    new = np.asarray(grid_lib.refine(edges, jnp.asarray(dsum), alpha=0.75))
    assert new.shape == (2, nb + 1)
    np.testing.assert_allclose(new[:, 0], 0.0)
    np.testing.assert_allclose(new[:, -1], 1.0)
    assert np.all(np.diff(new, axis=1) > 0), "edges must stay increasing"
    # axis 0 should devote more than half its bins to the mass-bearing quarter
    assert np.searchsorted(new[0], 0.25) > nb // 2
    # the flat axis stays (approximately) uniform
    np.testing.assert_allclose(new[1], np.linspace(0, 1, nb + 1), atol=0.02)


def test_refine_zero_mass_keeps_grid():
    edges = grid_lib.refine(
        grid_lib.uniform_edges(2, 8), jnp.zeros((2, 8)), alpha=0.75
    )
    np.testing.assert_allclose(
        np.asarray(edges), np.asarray(grid_lib.uniform_edges(2, 8))
    )


# --- stratification -----------------------------------------------------------


def test_choose_n_strat_budget_bound():
    for d, n, n_min in [(2, 8192, 4), (5, 8192, 4), (10, 8192, 4), (15, 8192, 4)]:
        ns = stratified.choose_n_strat(d, n, n_min)
        assert ns >= 1
        assert ns**d * 2 * n_min <= n
        assert (ns + 1) ** d * 2 * n_min > n


@pytest.mark.parametrize("weights", ["uniform", "zero", "spiky"])
def test_allocate_counts_conserves_total(weights):
    m, n, n_min = 64, 4096, 4
    w = {
        "uniform": np.ones(m),
        "zero": np.zeros(m),
        "spiky": np.eye(1, m, 7)[0] * 1e6,
    }[weights]
    counts = np.asarray(stratified.allocate_counts(jnp.asarray(w), n, n_min))
    assert counts.sum() == n
    assert counts.min() >= n_min


def test_cube_digits_roundtrip():
    n_strat, d = 3, 4
    cube = jnp.arange(n_strat**d, dtype=jnp.int32)
    digits = np.asarray(stratified.cube_digits(cube, n_strat, d))
    powers = n_strat ** np.arange(d)
    np.testing.assert_array_equal((digits * powers[:, None]).sum(0), np.asarray(cube))


# --- engine: determinism + backend config -------------------------------------


def _cfg(**kw):
    base = dict(
        d=3,
        integrand="f4",
        rel_tol=1e-3,
        backend="vegas",
        mc_samples=2048,
        mc_max_iters=20,
    )
    base.update(kw)
    return QuadratureConfig(**base)


def test_seeded_prng_determinism():
    a = integrate_vegas(_cfg())
    b = integrate_vegas(_cfg())
    assert a.integral == b.integral and a.error == b.error
    assert a.n_evals == b.n_evals and a.iterations == b.iterations
    c = integrate_vegas(_cfg(mc_seed=7))
    assert c.integral != a.integral, "different seed must draw different samples"


def test_backend_resolution_and_validation():
    assert QuadratureConfig(d=5, backend="auto").resolved_backend() == "cubature"
    assert QuadratureConfig(d=9, backend="auto").resolved_backend() == "vegas"
    assert (
        QuadratureConfig(d=15, backend="auto", auto_backend_dim=20).resolved_backend()
        == "cubature"
    )
    with pytest.raises(ValueError, match="backend"):
        QuadratureConfig(d=3, backend="mcmc").validate()
    with pytest.raises(ValueError, match="mc_samples"):
        QuadratureConfig(d=3, mc_samples=1000, mc_shards=7).validate()
    with pytest.raises(ValueError, match="mc_max_iters"):
        QuadratureConfig(d=3, mc_max_iters=2, mc_warmup=5).validate()


def test_iterate_accumulates_only_after_warmup():
    cfg = _cfg(mc_warmup=3)
    iterate = jax.jit(make_iterate(cfg, get_integrand("f4").fn))
    state = init_state(cfg)
    for i in range(5):
        state, m = iterate(state)
        assert int(m["n_acc"]) == max(0, i + 1 - 3)
    assert float(state.n_evals) == 5 * cfg.mc_samples


# --- statistical correctness --------------------------------------------------

FAMILY_THETAS = {
    "genz_gaussian": lambda d: {"a": np.full(d, 5.0), "u": np.full(d, 0.4)},
    "genz_product_peak": lambda d: {"a": np.full(d, 5.0), "u": np.full(d, 0.6)},
    "monomial": lambda d: {"p": np.arange(d, dtype=np.float64) % 5},
}


@pytest.mark.parametrize("family", sorted(FAMILY_THETAS))
@pytest.mark.parametrize("d", [5, 10])
def test_estimate_within_5_sigma_of_exact(family, d):
    fam = PARAM_REGISTRY[family]
    theta = FAMILY_THETAS[family](d)
    spec = f"{family}:" + ":".join(
        ",".join(repr(float(v)) for v in theta[k]) for k in fam.theta_fields
    )
    cfg = QuadratureConfig(
        d=d,
        integrand=spec,
        rel_tol=1e-3,
        backend="vegas",
        mc_samples=4096,
        mc_max_iters=40,
    )
    res = integrate_vegas(cfg)
    exact = fam.exact(d, theta)
    assert res.error > 0
    assert abs(res.integral - exact) < 5 * res.error, (
        f"{spec}: est {res.integral} exact {exact} error {res.error}"
    )
    # and the error estimate actually did some work (not vacuously huge)
    assert res.error < 0.1 * abs(exact)


def test_chi2_guard_on_discontinuous_integrand():
    """f6 (discontinuous) is the case the chi2/dof guard exists for: the
    per-iteration error bars understate, iterations disagree, chi2/dof
    rises above 1 and the reported error is inflated accordingly."""
    cfg = QuadratureConfig(
        d=3,
        integrand="f6",
        rel_tol=1e-6,  # unreachable: forces a full mc_max_iters history
        backend="vegas",
        mc_samples=4096,
        mc_max_iters=25,
    )
    res = integrate_vegas(cfg)
    assert np.isfinite(res.chi2_dof) and res.chi2_dof > 0
    exact = get_integrand("f6").exact(3)
    naive_sigma = res.error / max(np.sqrt(max(res.chi2_dof, 1.0)), 1.0)
    if res.chi2_dof > 1:
        assert res.error > naive_sigma, "inconsistency must inflate the error"
    # even on a discontinuity the estimate lands in the right place
    assert abs(res.integral - exact) < 0.05 * abs(exact)


def test_result_summary_mentions_chi2():
    res = integrate_vegas(_cfg())
    assert "chi2/dof" in res.summary()


# --- single- vs multi-device parity (subprocess: forces virtual devices) ------


@pytest.mark.parametrize("n_dev", [4])
def test_multi_device_bit_parity(n_dev):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.mc.multi_device", str(n_dev)],
        capture_output=True,
        text=True,
        timeout=1200,
        cwd=REPO,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    assert line, proc.stdout[-4000:]
    out = json.loads(line[-1][len("RESULT_JSON:") :])
    assert out["device_counts"] == [1, 2, n_dev]
    for case in out["cases"]:
        for p in case["parity"]:
            assert p["bit_identical"], case
        # sample totals are device-count-invariant: n_evals comes from the
        # single-device run and every parity entry matched it bit-exactly
        assert case["n_evals"] > 0


# --- the service pool ---------------------------------------------------------


def test_vegas_batch_service_end_to_end():
    from repro.service import integrate_batch

    fam = PARAM_REGISTRY["genz_gaussian"]
    rng = np.random.default_rng(3)
    d = 5
    thetas = [fam.sample_theta(d, rng) for _ in range(6)]
    cfg = QuadratureConfig(
        d=d,
        integrand="genz_gaussian",
        rel_tol=1e-3,
        backend="vegas",
        batch_slots=2,
        mc_samples=2048,
        mc_max_iters=40,
    )
    results = integrate_batch(cfg, thetas)
    assert len(results) == len(thetas)
    for r in results:
        assert r.status in ("converged", "max_iters")
        exact = fam.exact(d, thetas[r.req_id])
        assert abs(r.integral - exact) < 5 * r.error
        assert r.n_evals == cfg.mc_samples * r.iterations


def test_vegas_pool_rejects_multi_device():
    from repro.mc.engine import VegasBatchEngine

    with pytest.raises(ValueError, match="single-device"):
        VegasBatchEngine(
            _cfg(integrand="genz_gaussian", service_devices=4), "genz_gaussian"
        )


def test_auto_backend_routes_service_by_dimension():
    from repro.service.scheduler import make_engine
    from repro.mc.engine import VegasBatchEngine
    from repro.service.batch_engine import BatchEngine

    lo = make_engine(
        QuadratureConfig(d=3, integrand="genz_gaussian", backend="auto")
    )
    hi = make_engine(
        QuadratureConfig(
            d=9,
            integrand="genz_gaussian",
            backend="auto",
            mc_samples=2048,
        )
    )
    assert isinstance(lo, BatchEngine) and not isinstance(lo, VegasBatchEngine)
    assert isinstance(hi, VegasBatchEngine)


# --- chi^2/dof guard boundaries -----------------------------------------------


def test_chi2_single_accumulated_iteration_boundary():
    """mc_max_iters = mc_warmup + 1: exactly one post-warmup iteration.

    With n_acc=1 there is no dof for the consistency check, so the guard
    must (a) not divide by zero, (b) report chi2/dof = 0 and the raw
    (uninflated) sigma, and (c) refuse to converge no matter how loose the
    tolerance — a lucky single iteration has no error bar behind it."""
    cfg = QuadratureConfig(
        d=3,
        integrand="genz_gaussian",
        rel_tol=1e30,  # absurdly loose: only MIN_ACCUMULATED can block
        backend="vegas",
        mc_samples=2048,
        mc_warmup=2,
        mc_max_iters=3,
    )
    res = integrate_vegas(cfg, integrand=lambda x: jnp.prod(x, axis=0))
    assert res.status == "max_iters"
    assert res.iterations == 3
    assert res.chi2_dof == 0.0
    assert np.isfinite(res.error) and res.error > 0.0
    exact = 0.5**3
    assert abs(res.integral - exact) < 5 * res.error


def test_chi2_inflation_on_discontinuous_integrand():
    """Iteration estimates of a discontinuous integrand scatter more than
    their per-iteration sigmas admit: chi^2/dof must exceed 1 and the
    reported error must carry the sqrt(chi^2/dof) inflation."""
    cfg = QuadratureConfig(
        d=2,
        integrand="genz_gaussian",
        rel_tol=1e-4,
        backend="vegas",
        mc_samples=512,
        mc_warmup=2,
        mc_max_iters=40,
    )
    exact = 0.25  # corner-indicator volume
    res = integrate_vegas(
        cfg, integrand=lambda x: jnp.where(jnp.all(x < 0.5, axis=0), 1.0, 0.0)
    )
    assert res.chi2_dof > 1.0
    # error = sigma * sqrt(chi2/dof): backing the inflation out must SHRINK
    # the bar, i.e. the inflation really is applied
    raw_sigma = res.error / np.sqrt(res.chi2_dof)
    assert raw_sigma < res.error
    assert np.isfinite(res.integral)
    # the estimate itself stays sane (inflation flags the bar, not the value)
    assert abs(res.integral - exact) < 0.02
