"""Pallas GM kernel vs pure-jnp oracle: shape/dtype/block sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integrands
from repro.kernels import ops
from repro.kernels.ref import genz_malik_eval_soa_ref


def _random_regions(rng, b, d, dtype):
    centers = rng.uniform(0.1, 0.9, (b, d)).astype(dtype)
    halfw = rng.uniform(0.01, 0.1, (b, d)).astype(dtype)
    return jnp.asarray(centers), jnp.asarray(halfw)


@pytest.mark.parametrize("d", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("b", [64, 256])
def test_kernel_matches_ref_shapes(d, b):
    rng = np.random.default_rng(d * 100 + b)
    centers, halfw = _random_regions(rng, b, d, np.float64)
    f = integrands.get("f4").fn

    i7k, i5k, i3k, dk = ops.genz_malik_eval(f, centers, halfw, interpret=True)
    i7r, i5r, i3r, dr = genz_malik_eval_soa_ref(f, centers.T, halfw.T)

    np.testing.assert_allclose(i7k, i7r, rtol=1e-12, atol=1e-300)
    np.testing.assert_allclose(i5k, i5r, rtol=1e-12, atol=1e-300)
    np.testing.assert_allclose(i3k, i3r, rtol=1e-12, atol=1e-300)
    # fourth differences are differences of near-equal tiny numbers; compare
    # at a scale-relative absolute tolerance
    np.testing.assert_allclose(
        dk, dr.T, rtol=1e-8, atol=float(np.max(np.abs(dr))) * 1e-10
    )


@pytest.mark.parametrize("name", ["f1", "f2", "f3", "f5", "f6", "f7"])
def test_kernel_matches_ref_integrands(name):
    rng = np.random.default_rng(7)
    d, b = 4, 128
    centers, halfw = _random_regions(rng, b, d, np.float64)
    f = integrands.get(name).fn
    i7k, *_ = ops.genz_malik_eval(f, centers, halfw, interpret=True)
    i7r, *_ = genz_malik_eval_soa_ref(f, centers.T, halfw.T)
    np.testing.assert_allclose(i7k, i7r, rtol=1e-12, atol=1e-300)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 1e-3), (np.float64, 1e-12)])
def test_kernel_dtypes(dtype, rtol):
    rng = np.random.default_rng(3)
    d, b = 3, 128
    centers, halfw = _random_regions(rng, b, d, dtype)
    f = integrands.get("f1").fn
    i7k, *_ = ops.genz_malik_eval(f, centers, halfw, interpret=True)
    assert i7k.dtype == dtype
    # compare against the float64 oracle
    i7r, *_ = genz_malik_eval_soa_ref(
        f, centers.T.astype(np.float64), halfw.T.astype(np.float64)
    )
    np.testing.assert_allclose(i7k, i7r, rtol=rtol)


@pytest.mark.parametrize("block", [32, 64, 128, 512])
def test_kernel_block_sizes(block):
    rng = np.random.default_rng(11)
    d, b = 3, 192  # not a multiple of most blocks -> exercises padding
    centers, halfw = _random_regions(rng, b, d, np.float64)
    f = integrands.get("f3").fn
    i7k, i5k, _, dk = ops.genz_malik_eval(
        f, centers, halfw, block_regions=block, interpret=True
    )
    i7r, i5r, _, dr = genz_malik_eval_soa_ref(f, centers.T, halfw.T)
    np.testing.assert_allclose(i7k, i7r, rtol=1e-12)
    np.testing.assert_allclose(i5k, i5r, rtol=1e-12)
    assert dk.shape == (b, d)


def test_rule_with_kernel_integrates():
    """End-to-end: adaptive driver with the kernel path enabled."""
    from repro.core.adaptive import integrate
    from repro.core.config import QuadratureConfig

    cfg = QuadratureConfig(
        d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 12, use_kernel=True
    )
    res = integrate(cfg)
    exact = integrands.get("f4").exact(3)
    assert res.status == "converged"
    assert abs(res.integral - exact) / abs(exact) <= 5e-6


# --- ParamIntegrand families through the theta-operand kernel path ------------


@pytest.mark.parametrize(
    "name", ["genz_gaussian", "genz_product_peak", "monomial"]
)
@pytest.mark.parametrize("d", [2, 3, 5])
def test_theta_kernel_matches_ref_families(name, d):
    """Kernel with theta as an operand vs the jnp oracle with theta closed
    over — agreement at the repo's kernel/oracle tolerance (the two are
    separately compiled programs, so last-ulp FMA-contraction differences
    are expected exactly as for the fixed integrands above)."""
    rng = np.random.default_rng(d * 10 + len(name))
    fam = integrands.get_param(name)
    theta = fam.sample_theta(d, rng)
    centers, halfw = _random_regions(rng, 192, d, np.float64)
    i7k, i5k, i3k, dk = ops.genz_malik_eval(
        fam.fn, centers, halfw, theta=theta, interpret=True
    )
    i7r, i5r, i3r, dr = genz_malik_eval_soa_ref(
        lambda x: fam.fn(x, theta), centers.T, halfw.T
    )
    np.testing.assert_allclose(i7k, i7r, rtol=1e-12, atol=1e-300)
    np.testing.assert_allclose(i5k, i5r, rtol=1e-12, atol=1e-300)
    np.testing.assert_allclose(i3k, i3r, rtol=1e-12, atol=1e-300)
    # fourth differences can sit entirely at rounding noise (low-degree
    # monomials are near-exact for the embedded rules): compare at a
    # scale-relative tolerance with an eps-level absolute floor
    np.testing.assert_allclose(
        dk,
        dr.T,
        rtol=1e-8,
        atol=float(np.max(np.abs(np.asarray(dr)))) * 1e-10 + 1e-14,
    )


def test_make_rule_accepts_family_spec_with_kernel():
    """The family-spec rejection is gone: the kernel path parses the spec
    and feeds theta through the operand protocol."""
    from repro.core.config import QuadratureConfig
    from repro.core.rules import make_rule

    cfg = QuadratureConfig(
        d=2, integrand="genz_gaussian:5,5:0.3,0.7", use_kernel=True
    )
    rule = make_rule(cfg)
    assert rule.theta is not None
    rng = np.random.default_rng(0)
    centers, halfw = _random_regions(rng, 64, 2, np.float64)
    est, err, axis = rule.eval_batch(centers, halfw)
    assert est.shape == (64,)
    assert np.all(np.asarray(err) >= 0)


def test_kernel_family_spec_integrates_to_exact():
    """End-to-end serial driver on a family spec with the fused kernel."""
    from repro.core.adaptive import integrate
    from repro.core.config import QuadratureConfig

    spec = "genz_gaussian:6,4:0.3,0.7"
    cfg = QuadratureConfig(
        d=2, integrand=spec, rel_tol=1e-7, capacity=1 << 10, use_kernel=True
    )
    res = integrate(cfg)
    exact = integrands.get(spec).exact(2)
    assert res.status == "converged"
    assert abs(res.integral - exact) / abs(exact) <= 5e-7


@pytest.mark.parametrize("name", ["genz_gaussian", "genz_product_peak", "monomial"])
def test_batch_engine_kernel_path_matches_serial(name):
    """The service's vmapped kernel path is bit-identical to the serial
    kernel driver (theta through the operand protocol on both sides) — the
    parity guarantee continuous batching promises, now for use_kernel=True."""
    from repro.core.adaptive import integrate
    from repro.core.config import QuadratureConfig
    from repro.service.api import integrate_batch

    fam = integrands.get_param(name)
    rng = np.random.default_rng(17)
    thetas = [fam.sample_theta(2, rng) for _ in range(3)]
    base = dict(
        d=2, integrand=name, rel_tol=1e-6, capacity=1 << 9, batch_slots=2,
        max_iters=80, use_kernel=True,
    )
    results = integrate_batch(QuadratureConfig(**base), thetas, fam)
    for theta, r in zip(thetas, results):
        spec = name + ":" + ":".join(
            ",".join(repr(float(v)) for v in theta[k]) for k in fam.theta_fields
        )
        serial = integrate(QuadratureConfig(**{**base, "integrand": spec}))
        assert r.status == serial.status
        assert r.iterations == serial.iterations
        assert r.integral == serial.integral
        assert r.error == serial.error
        assert r.n_evals == serial.n_evals
