"""Exactness and structural tests for the embedded Genz-Malik family.

Exactness is checked on *random polynomials* of the target degree: by
linearity, exactness on one random polynomial with dense monomial support
verifies exactness on every monomial simultaneously (up to float roundoff).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import genz_malik


def _random_poly(d, max_degree, seed):
    """Random polynomial with all monomials of total degree <= max_degree."""
    powers = [
        p
        for p in itertools.product(range(max_degree + 1), repeat=d)
        if sum(p) <= max_degree
    ]
    rng = np.random.default_rng(seed)
    coef = rng.uniform(-1.0, 1.0, len(powers))
    P = np.array(powers, np.float64)  # (n_terms, d)

    def f(x):  # x: (d, N)
        # (n_terms, N) = prod over axes of x^p
        terms = jnp.prod(x[None, :, :] ** jnp.asarray(P)[:, :, None], axis=1)
        return jnp.asarray(coef) @ terms

    def exact_box(center, halfw):
        val = 0.0
        for cf, p in zip(coef, powers):
            term = cf
            for pi, c, h in zip(p, center, halfw):
                a, b = c - h, c + h
                term *= (b ** (pi + 1) - a ** (pi + 1)) / (pi + 1)
            val += term
        return val

    return f, exact_box


def _integrate_box(f, center, halfw):
    c = jnp.asarray(center, jnp.float64)[None, :]
    h = jnp.asarray(halfw, jnp.float64)[None, :]
    i7, i5, i3, diffs = jax.jit(genz_malik.gm_eval_reference, static_argnums=0)(
        f, c, h
    )
    return float(i7[0]), float(i5[0]), float(i3[0]), np.asarray(diffs[0])


@pytest.mark.parametrize("d", [1, 2, 3, 4, 5, 6])
def test_degree7_exact(d):
    f, exact_box = _random_poly(d, 7, seed=d)
    center, halfw = [0.5] * d, [0.5] * d
    i7, _, _, _ = _integrate_box(f, center, halfw)
    assert i7 == pytest.approx(exact_box(center, halfw), rel=1e-11, abs=1e-12)


@pytest.mark.parametrize("d", [1, 2, 3, 4])
def test_degree5_and_degree3_exact(d):
    f5, exact5 = _random_poly(d, 5, seed=10 + d)
    center, halfw = [0.3] * d, [0.4] * d
    _, i5, _, _ = _integrate_box(f5, center, halfw)
    assert i5 == pytest.approx(exact5(center, halfw), rel=1e-11, abs=1e-12)

    f3, exact3 = _random_poly(d, 3, seed=20 + d)
    _, _, i3, _ = _integrate_box(f3, center, halfw)
    assert i3 == pytest.approx(exact3(center, halfw), rel=1e-11, abs=1e-12)


def test_not_exact_beyond_degree():
    # x^8 in 1-D must NOT be integrated exactly by the degree-7 rule.
    def f(x):
        return x[0] ** 8

    i7, _, _, _ = _integrate_box(f, [0.0], [1.0])
    assert abs(i7 - 2.0 / 9.0) > 1e-6


@pytest.mark.parametrize("d", [2, 3, 5, 8])
def test_n_nodes_formula(d):
    assert genz_malik.n_nodes(d) == 1 + 4 * d + 2 * d * (d - 1) + 2**d


def test_subdivision_consistency():
    # Summed halves agree with the whole box at rule accuracy.
    def f(x):
        return jnp.sin(x[0]) * jnp.exp(-x[1]) + x[2] ** 3

    whole, *_ = _integrate_box(f, [0.0, 0.0, 0.0], [1.0, 1.0, 1.0])
    left, *_ = _integrate_box(f, [-0.5, 0.0, 0.0], [0.5, 1.0, 1.0])
    right, *_ = _integrate_box(f, [0.5, 0.0, 0.0], [0.5, 1.0, 1.0])
    assert whole == pytest.approx(left + right, rel=1e-4, abs=1e-6)


def test_fourth_difference_picks_rough_axis():
    def f(x):
        return jnp.cos(20.0 * x[1]) + 0.01 * x[0]

    _, _, _, diffs = _integrate_box(f, [0.5, 0.5, 0.5], [0.5, 0.5, 0.5])
    assert int(np.argmax(diffs)) == 1


def test_batch_consistency():
    rng = np.random.default_rng(0)
    d, b = 4, 17

    def f(x):
        return jnp.exp(-jnp.sum(x**2, axis=0))

    centers = rng.uniform(0.2, 0.8, (b, d))
    halfw = rng.uniform(0.05, 0.2, (b, d))
    ev = jax.jit(genz_malik.gm_eval_reference, static_argnums=0)
    i7b, i5b, i3b, diffb = ev(f, jnp.asarray(centers), jnp.asarray(halfw))
    i7s, i5s, _, diffs = ev(f, jnp.asarray(centers[:1]), jnp.asarray(halfw[:1]))
    np.testing.assert_allclose(i7b[0], i7s[0], rtol=1e-13)
    np.testing.assert_allclose(i5b[0], i5s[0], rtol=1e-13)
    np.testing.assert_allclose(diffb[0], diffs[0], rtol=1e-12, atol=1e-15)
