"""Lint: no bare ``print(`` in ``src/repro/`` (tier-1).

Human-facing progress goes through ``logging`` (see
``repro.telemetry.logutil``), machine-facing output is either the
``RESULT_JSON:`` wire format the selftests emit (one JSON blob on the last
stdout line, parsed by CI and the test suite) or a CLI entry point whose
stdout *is* its interface.  Everything else printing to stdout is a bug:
it interleaves with the RESULT_JSON protocol and cannot be silenced by
``--quiet``.
"""

import os
import re

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO, "src", "repro")

#: CLI entry points whose stdout is the user interface (argparse tools that
#: write results/diagnostics directly); relative to src/repro/
ALLOWED_FILES = {
    "launch/integrate.py",
    "launch/dryrun.py",
    "launch/serve.py",
    "launch/train.py",
    "telemetry/check.py",
    "perf/machine.py",
    "perf/catalog.py",
    "perf/regress.py",
    "perf/report.py",
}

_PRINT = re.compile(r"^\s*print\(")


def test_no_bare_print_in_src():
    offenders = []
    for dirpath, _dirnames, filenames in os.walk(_SRC):
        for fname in filenames:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, _SRC)
            if rel in ALLOWED_FILES:
                continue
            with open(path, encoding="utf-8") as fh:
                for lineno, line in enumerate(fh, 1):
                    if _PRINT.match(line) and "RESULT_JSON" not in line:
                        offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "bare print() in src/repro/ — route through logging "
        "(repro.telemetry.logutil) or add a RESULT_JSON: prefix:\n"
        + "\n".join(offenders)
    )


def test_serve_quad_is_print_free():
    """The serving CLI is fully on logging + telemetry sinks; keep it that
    way (it used to print per-result lines that ``--quiet`` couldn't stop)."""
    path = os.path.join(_SRC, "launch", "serve_quad.py")
    with open(path, encoding="utf-8") as fh:
        offenders = [
            f"{lineno}: {line.strip()}"
            for lineno, line in enumerate(fh, 1)
            if _PRINT.match(line)
        ]
    assert not offenders, offenders
