"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import genz_malik
from repro.core.redistribution import make_schedule, ring_perms
from repro.core.region_store import uniform_partition
from repro.models.layers import blockwise_attention, rmsnorm, rmsnorm_init

_SETTINGS = dict(max_examples=20, deadline=None)


# --- quadrature invariants ----------------------------------------------------


@given(
    d=st.integers(1, 5),
    m=st.integers(0, 6),
    lo=st.floats(-2.0, 0.0),
    width=st.floats(0.1, 3.0),
)
@settings(**_SETTINGS)
def test_uniform_partition_conserves_volume(d, m, lo, width):
    los = np.full(d, lo)
    his = los + width
    centers, halfw = uniform_partition(los, his, 2**m)
    assert centers.shape == (2**m, d)
    total = np.sum(np.prod(2 * halfw, axis=1))
    assert np.isclose(total, width**d, rtol=1e-10)
    assert np.all(centers - halfw >= los - 1e-12)
    assert np.all(centers + halfw <= his + 1e-12)


@given(
    d=st.integers(1, 4),
    degree=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**_SETTINGS)
def test_gm_rule_exact_on_random_monomial(d, degree, seed):
    rng = np.random.default_rng(seed)
    # random powers with total degree <= 7
    powers = np.zeros(d, np.int64)
    remaining = degree
    for i in range(d):
        p = rng.integers(0, remaining + 1)
        powers[i] = p
        remaining -= p

    def f(x):
        return jnp.prod(x ** jnp.asarray(powers, x.dtype)[:, None], axis=0)

    c = jnp.full((1, d), 0.5, jnp.float64)
    h = jnp.full((1, d), 0.5, jnp.float64)
    i7, _, _, _ = genz_malik.gm_eval_reference(f, c, h)
    exact = float(np.prod(1.0 / (powers + 1.0)))
    assert np.isclose(float(i7[0]), exact, rtol=1e-10, atol=1e-12)


@given(seed=st.integers(0, 2**31 - 1), axis=st.integers(0, 2))
@settings(**_SETTINGS)
def test_split_children_partition_parent(seed, axis):
    """Volume + containment invariants of axis bisection (any box, any axis)."""
    rng = np.random.default_rng(seed)
    center = rng.uniform(-1, 1, 3)
    halfw = rng.uniform(0.05, 1.0, 3)
    h_child = halfw.copy()
    h_child[axis] *= 0.5
    ca = center.copy()
    ca[axis] -= h_child[axis]
    cb = center.copy()
    cb[axis] += h_child[axis]
    # children tile the parent: volumes sum, bounds match
    assert np.isclose(2 * np.prod(2 * h_child), np.prod(2 * halfw))
    assert np.isclose(ca[axis] - h_child[axis], center[axis] - halfw[axis])
    assert np.isclose(cb[axis] + h_child[axis], center[axis] + halfw[axis])
    assert np.isclose(ca[axis] + h_child[axis], cb[axis] - h_child[axis])


# --- cyclic redistribution schedule invariants ----------------------------------


@given(n=st.integers(0, 5000), max_len=st.integers(1, 16))
@settings(**_SETTINGS)
def test_schedule_shifts_unique_bounded_in_range(n, max_len):
    """Any ring size, any budget: shifts are unique, within the budget, and
    always a valid ring distance (never 0 = self-pairing)."""
    sched = make_schedule(n, max_len)
    assert len(sched) == len(set(sched))
    assert len(sched) <= max_len
    for s in sched:
        assert 1 <= s < n
    if n > 1:
        assert sched[0] == 1, "unit stride must lead the schedule"


@given(n=st.integers(2, 64))
@settings(**_SETTINGS)
def test_schedule_visits_every_ring_shift_when_budget_allows(n):
    """With budget for all n-1 distances, every one is visited — any
    imbalance pattern is eventually smoothed regardless of where it sits."""
    sched = make_schedule(n, max_len=n - 1)
    assert set(sched) == set(range(1, n))


@given(n=st.integers(2, 128), shift=st.integers(1, 127))
@settings(**_SETTINGS)
def test_ring_perms_are_self_pair_free_bijections(n, shift):
    """Both ppermute index lists of a round are bijections of the ring with
    no rank paired to itself, and they are mutual inverses (the stats that
    go down come back up)."""
    shift = 1 + shift % (n - 1) if n > 1 else 0
    down, up = ring_perms(n, shift)
    for perm in (down, up):
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert sorted(srcs) == list(range(n))
        assert sorted(dsts) == list(range(n))
        assert all(s != d for s, d in perm), "rank paired with itself"
    assert {(d, s) for s, d in down} == set(up)


# --- model invariants -----------------------------------------------------------


@given(seed=st.integers(0, 1000), t=st.integers(1, 30))
@settings(max_examples=10, deadline=None)
def test_causal_attention_ignores_future(seed, t):
    """Output at position t must not change when tokens after t change."""
    rng = np.random.default_rng(seed)
    b, s, h, hd = 1, 32, 2, 8
    q = rng.standard_normal((b, s, h, hd)).astype(np.float32)
    k = rng.standard_normal((b, s, h, hd)).astype(np.float32)
    v = rng.standard_normal((b, s, h, hd)).astype(np.float32)
    out1 = blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True, kv_block=8
    )
    k2, v2 = k.copy(), v.copy()
    k2[:, t:] = rng.standard_normal(k2[:, t:].shape)
    v2[:, t:] = rng.standard_normal(v2[:, t:].shape)
    out2 = blockwise_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), causal=True, kv_block=8
    )
    np.testing.assert_allclose(
        np.asarray(out1[:, :t]), np.asarray(out2[:, :t]), rtol=1e-5, atol=1e-5
    )


@given(seed=st.integers(0, 1000), scale=st.floats(0.1, 100.0))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_scale_invariance(seed, scale):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, 8, 16)).astype(np.float32)
    p = rmsnorm_init(16)
    a = rmsnorm(p, jnp.asarray(x))
    b = rmsnorm(p, jnp.asarray(scale * x))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


@given(block=st.sampled_from([4, 8, 16, 64]))
@settings(max_examples=8, deadline=None)
def test_blockwise_attention_block_invariance(block):
    """Result must not depend on the streaming block size."""
    rng = np.random.default_rng(0)
    b, s, h, hd = 1, 48, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    ref = blockwise_attention(q, k, v, causal=True, kv_block=48)
    out = blockwise_attention(q, k, v, causal=True, kv_block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
