"""Graceful degradation: quarantine, re-routing, deadlines, service resume.

In-process single-device unit tests for the fault-tolerance layer (the
multi-device chaos run lives in ``repro.service.chaos_selftest``, driven by
``test_chaos.py``): non-finite quarantine in the serial driver and both
engine pools, fallback re-routing with attempt provenance, deadline SLOs,
service checkpoint/resume parity, and the CheckpointManager async-error
regression.
"""

import dataclasses

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.adaptive import integrate, result_status
from repro.core.config import QuadratureConfig
from repro.core.integrands import get_param
from repro.service import (
    BatchScheduler,
    GracefulScheduler,
    QuadRequest,
    ReroutePolicy,
    ServiceCheckpointer,
)
from repro.service.faults import (
    NAN_SENTINEL,
    DeviceDown,
    DeviceLostError,
    SimulatedCrash,
    corrupt_slot_hook,
    crash_at,
    nan_family,
    poison_theta,
)
from repro.service.scheduler import decode_request, encode_request

FAMILY = get_param("genz_gaussian")


def _cfg(**kw):
    base = dict(
        d=2,
        integrand="genz_gaussian",
        rel_tol=1e-3,
        capacity=1 << 9,
        batch_slots=4,
        max_iters=60,
        sync_every=4,
    )
    base.update(kw)
    return QuadratureConfig(**base)


def _requests(n, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [
        QuadRequest(req_id=i, theta=FAMILY.sample_theta(2, rng), **kw)
        for i in range(n)
    ]


def _vals(results):
    return {
        r.req_id: (r.integral.hex(), r.error.hex(), r.status, r.iterations)
        for r in results
    }


# --- non-finite quarantine ----------------------------------------------------


def test_result_status_ranks_nonfinite_first():
    cfg = _cfg()
    assert result_status(True, 0, 3, cfg, False, nonfinite=True) == "nonfinite"
    assert result_status(True, 0, 3, cfg, False) == "converged"


def test_serial_integrate_quarantines_nan_integrand():
    wrapped = nan_family(FAMILY)
    theta = poison_theta(FAMILY.sample_theta(2, np.random.default_rng(0)))
    res = integrate(_cfg(), integrand=lambda x: wrapped.fn(x, theta))
    assert res.status == "nonfinite"
    assert np.isfinite(res.integral) and np.isfinite(res.error)


def test_nan_wrapper_is_identity_for_healthy_theta():
    wrapped = nan_family(FAMILY)
    theta = FAMILY.sample_theta(2, np.random.default_rng(1))
    base = integrate(_cfg(), integrand=lambda x: FAMILY.fn(x, theta))
    via = integrate(_cfg(), integrand=lambda x: wrapped.fn(x, theta))
    assert base.integral.hex() == via.integral.hex()
    assert base.error.hex() == via.error.hex()
    assert base.status == via.status == "converged"


def test_cubature_fleet_quarantine_contains_poison():
    """One NaN slot must not perturb healthy slots' bits, and is collected
    with status nonfinite instead of grinding to max_iters."""
    reqs = _requests(4)
    clean = BatchScheduler(_cfg(), FAMILY)
    base = _vals(clean.serve(list(reqs)))

    wrapped = nan_family(FAMILY)
    poisoned = reqs + [
        QuadRequest(req_id=99, theta=poison_theta(reqs[0].theta))
    ]
    sched = BatchScheduler(_cfg(), wrapped)
    results = list(sched.serve(poisoned))
    vals = _vals(results)
    bad = vals.pop(99)
    assert bad[2] == "nonfinite"
    assert vals == base
    assert sched.last_stats["quarantines"] == 1


def test_vegas_fleet_quarantine():
    wrapped = nan_family(FAMILY)
    cfg = _cfg(backend="vegas", mc_samples=512, mc_max_iters=20)
    reqs = _requests(2, rel_tol=1e-2) + [
        QuadRequest(
            req_id=50,
            theta=poison_theta(FAMILY.sample_theta(2, np.random.default_rng(5))),
        )
    ]
    sched = BatchScheduler(cfg, wrapped)
    results = list(sched.serve(reqs))
    by_id = {r.req_id: r for r in results}
    assert by_id[50].status == "nonfinite"
    assert by_id[50].backend == "vegas"
    for i in (0, 1):
        assert by_id[i].status in ("converged", "max_iters")
        assert np.isfinite(by_id[i].integral)
    assert sched.last_stats["quarantines"] == 1


# --- fallback re-routing ------------------------------------------------------


def test_capacity_eviction_reroutes_to_vegas():
    """A region-store-starved cubature request must come back converged
    through the MC pool, with full attempt provenance."""
    cfg = _cfg(
        capacity=1 << 5, rel_tol=1e-7, mc_samples=4096, mc_max_iters=30
    )
    reqs = _requests(2)
    sched = BatchScheduler(cfg, FAMILY)
    statuses = {r.req_id: r.status for r in sched.serve(list(reqs))}
    assert "capacity" in statuses.values(), statuses  # scenario sanity

    graceful = GracefulScheduler(cfg, FAMILY)
    results = {r.req_id: r for r in graceful.serve(list(reqs))}
    assert len(results) == 2
    rerouted = [r for r in results.values() if r.attempts == 2]
    assert rerouted, results
    for r in rerouted:
        assert r.retried_from == "capacity"
        assert r.backend == "vegas"
        exact = FAMILY.exact(2, reqs[r.req_id].theta)
        assert abs(r.integral - exact) <= 1e-2 * abs(exact)
    assert graceful.last_stats["reroutes"] == len(rerouted)


def test_reroute_respects_attempt_budget():
    policy = ReroutePolicy(max_attempts=1)
    cfg = _cfg(capacity=1 << 5, rel_tol=1e-7)
    graceful = GracefulScheduler(cfg, FAMILY, policy=policy)
    results = list(graceful.serve(_requests(2)))
    assert all(r.attempts == 1 for r in results)
    assert any(r.status == "capacity" for r in results)
    assert graceful.last_stats["reroutes"] == 0


def test_reroute_policy_validation():
    with pytest.raises(ValueError):
        ReroutePolicy(max_attempts=0).validate()
    with pytest.raises(ValueError):
        ReroutePolicy(tol_relax=0.5).validate()


def test_slot_corruption_detected_and_rerouted():
    reqs = _requests(4)
    reqs[0] = dataclasses.replace(reqs[0], rel_tol=1e-7)
    graceful = GracefulScheduler(
        _cfg(), FAMILY, on_tick=corrupt_slot_hook(0, 1, req_id=0)
    )
    results = {r.req_id: r for r in graceful.serve(list(reqs))}
    assert results[0].retried_from == "nonfinite"
    assert results[0].backend == "vegas"
    assert np.isfinite(results[0].integral)


# --- deadlines ----------------------------------------------------------------


def test_max_evals_deadline_evicts_with_partial():
    reqs = _requests(4)
    reqs[0] = dataclasses.replace(reqs[0], rel_tol=1e-12, max_evals=2e4)
    sched = BatchScheduler(_cfg(capacity=1 << 11, max_iters=200), FAMILY)
    results = {r.req_id: r for r in sched.serve(list(reqs))}
    assert results[0].status == "deadline"
    assert results[0].n_evals > 2e4
    assert np.isfinite(results[0].integral)
    # the partial is a real estimate, not garbage
    exact = FAMILY.exact(2, reqs[0].theta)
    assert abs(results[0].integral - exact) <= 1e-3 * abs(exact)
    assert all(r.status == "converged" for i, r in results.items() if i != 0)
    assert sched.last_stats["deadlines"] == 1


def test_wall_clock_deadline_evicts():
    reqs = _requests(2)
    # deadline_s=0: expired at the first dispatch boundary, guaranteed
    reqs[0] = dataclasses.replace(reqs[0], rel_tol=1e-9, deadline_s=0.0)
    sched = BatchScheduler(_cfg(), FAMILY)
    results = {r.req_id: r for r in sched.serve(list(reqs))}
    assert results[0].status == "deadline"
    assert results[1].status == "converged"


# --- service checkpoint/resume ------------------------------------------------


def test_request_roundtrip_is_bit_exact():
    req = QuadRequest(
        req_id=7,
        theta=FAMILY.sample_theta(2, np.random.default_rng(3)),
        rel_tol=1e-7,
        deadline_s=2.5,
    )
    back = decode_request(encode_request(req), req.theta)
    assert back.req_id == req.req_id
    assert back.rel_tol == req.rel_tol and back.abs_tol is None
    assert back.deadline_s == 2.5 and back.max_evals is None
    import jax

    for a, b in zip(
        jax.tree_util.tree_leaves(req.theta), jax.tree_util.tree_leaves(back.theta)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_resume_union_is_bit_identical(tmp_path):
    cfg = _cfg()
    reqs = _requests(8)
    reqs[0] = dataclasses.replace(reqs[0], rel_tol=1e-8)
    baseline = BatchScheduler(cfg, FAMILY)
    want = _vals(baseline.serve(list(reqs)))

    ckpt = ServiceCheckpointer(str(tmp_path))
    crashing = BatchScheduler(
        cfg, FAMILY, checkpointer=ckpt, checkpoint_every=1, on_tick=crash_at(3)
    )
    pre = []
    with pytest.raises(SimulatedCrash):
        for r in crashing.serve(list(reqs)):
            pre.append(r)
    assert ckpt.latest_step() is not None
    resumed = BatchScheduler(cfg, FAMILY, checkpointer=ckpt)
    post = list(resumed.serve(list(reqs), resume=True))
    got = {}
    for r in pre + post:
        t = _vals([r])[r.req_id]
        assert got.setdefault(r.req_id, t) == t  # replays are bit-identical
    assert got == want


def test_scheduler_checkpoint_arg_validation(tmp_path):
    with pytest.raises(ValueError, match="requires a checkpointer"):
        BatchScheduler(_cfg(), FAMILY, checkpoint_every=2)
    sched = BatchScheduler(_cfg(), FAMILY)
    with pytest.raises(ValueError, match="requires a checkpointer"):
        next(iter(sched.serve(_requests(1), resume=True)))
    ckpt = ServiceCheckpointer(str(tmp_path))
    sched = BatchScheduler(_cfg(), FAMILY, checkpointer=ckpt)
    with pytest.raises(FileNotFoundError):
        next(iter(sched.serve(_requests(1), resume=True)))


# --- device loss: single-device watchdog paths --------------------------------
# (evacuation/shrink/regrow need a real multi-device mesh and live in
# repro.service.chaos_selftest, driven by test_chaos.py)


def test_transient_device_fault_retry_is_bit_identical():
    """A fault that clears within the retry budget must leave the run fully
    bit-identical to a fault-free one — scheduling decisions included."""
    reqs = _requests(4)
    clean = BatchScheduler(_cfg(), FAMILY)
    want = _vals(clean.serve(list(reqs)))

    sched = BatchScheduler(
        _cfg(),
        FAMILY,
        fault_injector=DeviceDown(device=0, at_tick=1, transient_failures=2),
        max_dispatch_retries=3,
        retry_backoff_s=0.0,
    )
    assert _vals(sched.serve(list(reqs))) == want
    assert sched.last_stats["dispatch_retries"] == 2
    assert sched.last_stats["evacuations"] == 0
    assert sched.last_stats["mesh_shrinks"] == 0


def test_permanent_loss_on_single_device_is_fatal():
    """No surviving sub-mesh to evacuate onto: the loss must propagate."""
    sched = BatchScheduler(
        _cfg(),
        FAMILY,
        fault_injector=DeviceDown(device=0, at_tick=1),
        max_dispatch_retries=1,
        retry_backoff_s=0.0,
    )
    with pytest.raises(DeviceLostError):
        list(sched.serve(_requests(2)))
    assert sched.last_stats["dispatch_retries"] == 1


def test_hung_dispatch_converted_to_timeout_and_retried():
    """mode='hang' wedges the dispatch; the watchdog must convert it into a
    retryable timeout rather than hanging the serve loop forever."""
    reqs = _requests(2)
    clean = BatchScheduler(_cfg(), FAMILY)
    want = _vals(clean.serve(list(reqs)))

    # the timeout must sit above the cost of a *genuine* dispatch — which on
    # CPU includes multi-second window-rung recompiles — and below the hang
    sched = BatchScheduler(
        _cfg(),
        FAMILY,
        fault_injector=DeviceDown(
            device=0, at_tick=1, transient_failures=1, mode="hang"
        ),
        max_dispatch_retries=2,
        dispatch_timeout_s=10.0,
        retry_backoff_s=0.0,
    )
    assert _vals(sched.serve(list(reqs))) == want
    assert sched.last_stats["dispatch_retries"] == 1


def test_hung_dispatch_permanent_raises_device_lost():
    sched = BatchScheduler(
        _cfg(),
        FAMILY,
        fault_injector=DeviceDown(device=0, at_tick=1, mode="hang"),
        max_dispatch_retries=0,
        dispatch_timeout_s=10.0,
        retry_backoff_s=0.0,
    )
    # the hang is attributed to device 0 via the injector's healthy() probe;
    # a single-device fleet then has nowhere to evacuate
    with pytest.raises(DeviceLostError):
        list(sched.serve(_requests(2)))


def test_device_down_injector_validation():
    with pytest.raises(ValueError, match="mode"):
        DeviceDown(device=0, at_tick=1, mode="explode")


# --- corrupted-snapshot fallback ----------------------------------------------


def test_restore_falls_back_past_corrupt_meta(tmp_path):
    """A truncated meta sidecar (torn write on a dirty filesystem) must not
    brick resume: restore() skips it and loads the previous snapshot."""
    import json

    sched = BatchScheduler(_cfg(), FAMILY)
    eng = sched.engine
    state = eng.init()
    ckpt = ServiceCheckpointer(str(tmp_path))
    meta = {"ticks": 1, "stats": {}, "pulled_ids": [], "slots": []}
    ckpt.save(1, state, dict(meta, it=4))
    ckpt.save(2, state, dict(meta, it=8))

    p = tmp_path / "meta_00000002.json"
    raw = p.read_bytes()
    p.write_bytes(raw[: len(raw) // 2])

    _, got = ckpt.restore(eng)
    assert got["it"] == 4  # fell back to step 1
    with pytest.raises(json.JSONDecodeError):
        ckpt.restore(eng, step=2)  # explicit step: no silent fallback


def test_restore_rejects_meta_missing_required_keys(tmp_path):
    import json

    sched = BatchScheduler(_cfg(), FAMILY)
    eng = sched.engine
    state = eng.init()
    ckpt = ServiceCheckpointer(str(tmp_path))
    ckpt.save(1, state, {"it": 2, "ticks": 1, "stats": {}, "pulled_ids": [], "slots": []})
    # valid JSON, but a partial dict: must be treated as corrupt, not restored
    ckpt.save(2, state, {"it": 9})
    # save() validates nothing (the writer trusts the scheduler); break it
    # after the fact to model a torn-but-parseable sidecar
    p = tmp_path / "meta_00000002.json"
    assert json.loads(p.read_text())["it"] == 9  # parseable ...
    _, got = ckpt.restore(eng)
    assert got["it"] == 2  # ... but restore fell back past it
    with pytest.raises(KeyError):
        ckpt.restore(eng, step=2)


def test_restore_raises_when_every_snapshot_corrupt(tmp_path):
    sched = BatchScheduler(_cfg(), FAMILY)
    eng = sched.engine
    ckpt = ServiceCheckpointer(str(tmp_path))
    meta = {"it": 1, "ticks": 1, "stats": {}, "pulled_ids": [], "slots": []}
    ckpt.save(1, eng.init(), meta)
    p = tmp_path / "meta_00000001.json"
    p.write_bytes(p.read_bytes()[:10])
    with pytest.raises(FileNotFoundError, match="all corrupt"):
        ckpt.restore(eng)


# --- CheckpointManager async-error regression ---------------------------------


def test_async_write_error_resurfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": np.arange(4.0)}
    mgr.save(1, tree, blocking=True)
    # re-saving an existing step fails in the background thread; before the
    # fix the FileExistsError died with the thread and the caller never knew
    mgr.save(1, tree)
    with pytest.raises(FileExistsError):
        mgr.wait()
    # the error is surfaced once, then the manager is usable again
    mgr.wait()
    mgr.save(2, tree)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_async_write_error_resurfaces_on_next_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": np.arange(4.0)}
    mgr.save(1, tree, blocking=True)
    mgr.save(1, tree)
    with pytest.raises(FileExistsError):
        mgr.save(3, tree)  # save() waits on the pending thread first
    mgr.save(3, tree)
    mgr.wait()
    assert mgr.latest_step() == 3


# --- injector hygiene ---------------------------------------------------------


def test_poison_theta_only_touches_first_leaf():
    theta = FAMILY.sample_theta(2, np.random.default_rng(0))
    bad = poison_theta(theta)
    import jax

    leaves = jax.tree_util.tree_leaves(theta)
    bad_leaves = jax.tree_util.tree_leaves(bad)
    assert np.all(np.asarray(bad_leaves[0]) == NAN_SENTINEL)
    for a, b in zip(leaves[1:], bad_leaves[1:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
