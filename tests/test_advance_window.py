"""Windowed advance: bit-identity with the full-capacity advance.

The tentpole guarantee of the advance-window refactor: running classify +
split/compact (and the global reductions) on a leading window
``w >= min(2 * n_active, capacity)`` is *bit-identical* to the legacy
full-capacity advance — same survivors in the same slots, same children,
same scalar accumulators, same overflow flags — in every regime including
capacity pressure and forced finalise.  Verified here three ways:

- a hypothesis property drives :func:`classify_split_compact` directly with
  random populations, window rungs, near-full stores and both classifiers;
- mid-trajectory states from a real driver are advanced at every valid rung;
- all four drivers (host, device-resident, distributed, batch service) are
  run end-to-end with ``advance_window`` on vs off and compared exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import region_store
from repro.core.adaptive import (
    advance_ladder,
    advance_target,
    integrate,
    integrate_device,
    make_advance_step,
)
from repro.core.classify import classify
from repro.core.config import QuadratureConfig
from repro.core.distributed import integrate_distributed
from repro.core.split import classify_split_compact, compact, survivor_sort_perm

try:  # hypothesis drives the property tests where available (CI); a
    # deterministic seeded sweep below covers minimal containers
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    _SETTINGS = dict(max_examples=40, deadline=None)
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)


def _random_state(rng, C, d, n_active, tiny_frac=0.3):
    """A plausible mid-flight store: contiguous actives, sorted or not."""
    st_ = region_store.empty_state(C, d, jnp.float64)
    centers = rng.uniform(0.1, 0.9, (C, d))
    halfw = rng.uniform(0.005, 0.1, (C, d))
    est = rng.standard_normal(C) * 10.0 ** rng.integers(-6, 3, C)
    err = np.abs(rng.standard_normal(C)) * 10.0 ** rng.integers(-12, 0, C)
    # a fraction of regions with near-zero error (classifier fodder)
    tiny = rng.random(C) < tiny_frac
    err[tiny] *= 1e-14
    active = np.arange(C) < n_active
    # duplicate some error keys to stress sort stability
    if n_active >= 4:
        err[: n_active // 2] = err[n_active // 2 : 2 * (n_active // 2)]
    return dataclasses.replace(
        st_,
        centers=jnp.asarray(centers),
        halfw=jnp.asarray(halfw),
        est=jnp.asarray(np.where(active, est, 0.0)),
        err=jnp.asarray(np.where(active, err, 0.0)),
        axis=jnp.asarray(rng.integers(0, d, C), jnp.int32),
        active=jnp.asarray(active),
        fin_integral=jnp.asarray(rng.standard_normal(), jnp.float64),
        fin_error=jnp.asarray(abs(rng.standard_normal()), jnp.float64),
    )


def _assert_bit_identical(full, win, context=""):
    """Full vs windowed advance results agree on everything observable.

    Freed-slot *garbage* may land in different slots (the full sort permutes
    the dead tail, the windowed one leaves it in place), but garbage is
    never re-exposed — so equality is asserted on the scalars, the masks,
    and every array restricted to the occupied block.
    """
    nf = int(jnp.sum(full.active))
    nw = int(jnp.sum(win.active))
    assert nf == nw, context
    assert np.array_equal(np.asarray(full.active), np.asarray(win.active)), context
    assert np.array_equal(np.asarray(full.fresh), np.asarray(win.fresh)), context
    assert float(full.fin_integral) == float(win.fin_integral), context
    assert float(full.fin_error) == float(win.fin_error), context
    assert bool(full.overflowed) == bool(win.overflowed), context
    for name in ("centers", "halfw", "est", "err", "axis"):
        a = np.asarray(getattr(full, name))[:nf]
        b = np.asarray(getattr(win, name))[:nf]
        assert np.array_equal(a, b), f"{context}: {name} differs in occupied block"
    # the invariant survives both paths: no active slot beyond the block
    assert not np.asarray(full.active)[nf:].any(), context
    assert not np.asarray(win.active)[nw:].any(), context


def _check_windowed_csc(log_c, pop, d, seed, classifier, escalate):
    """classify_split_compact at any valid window == full-capacity result.

    Populations sweep the whole range — including past the 3C/4
    forced-finalise limit and the k < n_act capacity-pressure regime — and
    the window is the driver's rung choice, optionally escalated (any wider
    valid window must agree too).
    """
    C = 1 << log_c
    n = int(round(pop * C))
    rng = np.random.default_rng(seed)
    state = _random_state(rng, C, d, n)

    if classifier == "random":
        mask = jnp.asarray(rng.random(C) < 0.3)
    else:
        cfg = QuadratureConfig(
            d=d, capacity=C, classifier=classifier, rel_tol=1e-6
        ).validate()
        integral, _ = state.global_estimates()
        mask = classify(
            cfg,
            state.est,
            state.err,
            state.halfw,
            state.active,
            integral,
            1.0,
            jnp.ones(d),
        )

    ladder = region_store.window_ladder(C, 16)
    w = region_store.select_window(ladder, advance_target(n, C))
    for _ in range(escalate):
        w = min(2 * w, C)

    full = classify_split_compact(state, mask)
    win = classify_split_compact(state, mask[:w], window=w)
    _assert_bit_identical(full, win, f"C={C} n={n} w={w} {classifier}")


def _check_windowed_compact(log_c, pop, seed):
    C = 1 << log_c
    n = int(round(pop * C))
    rng = np.random.default_rng(seed)
    state = _random_state(rng, C, 3, n)
    ladder = region_store.window_ladder(C, 16)
    w = region_store.select_window(ladder, n)
    full = compact(state)
    win = compact(state, window=w)
    nf = int(jnp.sum(full.active))
    for name in ("centers", "halfw", "est", "err", "axis", "active", "fresh"):
        a = np.asarray(getattr(full, name))[:nf]
        b = np.asarray(getattr(win, name))[:nf]
        assert np.array_equal(a, b), name


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(
        log_c=st.integers(6, 9),
        pop=st.floats(0.0, 1.0),
        d=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
        classifier=st.sampled_from(["robust", "aggressive", "random"]),
        escalate=st.integers(0, 2),
    )
    @settings(**_SETTINGS)
    def test_windowed_csc_bit_identical(log_c, pop, d, seed, classifier, escalate):
        _check_windowed_csc(log_c, pop, d, seed, classifier, escalate)

    @needs_hypothesis
    @given(
        log_c=st.integers(6, 8),
        pop=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**_SETTINGS)
    def test_windowed_compact_bit_identical(log_c, pop, seed):
        _check_windowed_compact(log_c, pop, seed)


@pytest.mark.parametrize("case", range(24))
def test_windowed_csc_bit_identical_sweep(case):
    """Deterministic fallback sweep over the same parameter space (always
    runs, even where hypothesis is unavailable)."""
    rng = np.random.default_rng(1000 + case)
    _check_windowed_csc(
        log_c=int(rng.integers(6, 10)),
        pop=float(rng.random()),
        d=int(rng.integers(1, 5)),
        seed=int(rng.integers(0, 2**31 - 1)),
        classifier=["robust", "aggressive", "random"][case % 3],
        escalate=case % 3,
    )


@pytest.mark.parametrize("case", range(8))
def test_windowed_compact_bit_identical_sweep(case):
    rng = np.random.default_rng(2000 + case)
    _check_windowed_compact(
        log_c=int(rng.integers(6, 9)),
        pop=float(rng.random()),
        seed=int(rng.integers(0, 2**31 - 1)),
    )


def test_survivor_sort_perm_shared_semantics():
    """The factored sort key compacts actives to the front by descending
    error with stable tie-breaks (the order both csc and compact rely on)."""
    err = jnp.asarray([0.5, 0.1, 0.5, 0.9, 0.0, 0.2])
    active = jnp.asarray([True, True, True, False, True, True])
    perm = np.asarray(survivor_sort_perm(err, active))
    # descending error among actives, stable for the duplicate 0.5s
    assert perm.tolist() == [0, 2, 5, 1, 4, 3]


def test_forced_finalise_regime_exercised():
    """Sanity-check the property covers the pressure path: a near-full store
    must set overflowed and force-finalise identically on both paths."""
    C = 128
    rng = np.random.default_rng(5)
    n = C - 4  # past 3C/4
    state = _random_state(rng, C, 2, n)
    mask = jnp.zeros(C, bool)  # classifier finalises nothing: pure pressure
    full = classify_split_compact(state, mask)
    win = classify_split_compact(state, mask, window=C)  # target escalates to C
    assert bool(full.overflowed) and bool(win.overflowed)
    assert float(full.fin_integral) == float(win.fin_integral)
    _assert_bit_identical(full, win, "forced finalise")


def test_mid_trajectory_advance_rungs():
    """Advance real driver states at every valid rung: all bit-identical."""
    cfg = QuadratureConfig(
        d=3, integrand="f2", rel_tol=1e-7, capacity=1 << 10, max_iters=40
    ).validate()
    states = []

    # harvest mid-trajectory states via the callback-free route: run the
    # host driver manually for a few iterations
    from repro.core.adaptive import _setup, make_eval_step

    cfg2, lo, hi, total_volume, rule, state = _setup(cfg, None)
    eval_step = jax.jit(make_eval_step(cfg2, rule))
    advance = jax.jit(make_advance_step(cfg2, total_volume, hi - lo))
    for _ in range(6):
        state = eval_step(state)
        states.append(state)
        state = advance(state)

    ladder = advance_ladder(cfg2)
    for i, s in enumerate(states):
        n = int(jnp.sum(s.active))
        full = make_advance_step(cfg2, total_volume, hi - lo)(s)
        target = advance_target(n, cfg2.capacity)
        for w in [r for r in ladder if r >= target]:
            win = make_advance_step(cfg2, total_volume, hi - lo, window=w)(s)
            _assert_bit_identical(full, win, f"iter={i} w={w}")
            assert int(win.it) == int(full.it)


# --- end-to-end driver parity -------------------------------------------------

PARITY_CASES = [
    # (integrand, d, rule, rel_tol, capacity)
    ("f4", 3, "genz_malik", 1e-7, 1 << 12),
    ("f1", 2, "gauss_kronrod", 1e-8, 1 << 11),
]


@pytest.mark.parametrize("name,d,rule,rel_tol,capacity", PARITY_CASES)
def test_host_driver_parity(name, d, rule, rel_tol, capacity):
    base = dict(
        d=d, integrand=name, rel_tol=rel_tol, capacity=capacity, rule=rule,
        max_iters=200,
    )
    traj = {}
    res = {}
    for on in (True, False):
        traj[on] = []
        res[on] = integrate(
            QuadratureConfig(advance_window=on, **base),
            callback=lambda *a, t=traj[on]: t.append(a),
        )
    assert res[True].status == res[False].status
    assert res[True].iterations == res[False].iterations
    assert traj[True] == traj[False]  # bit-identical per-iteration history
    assert res[True].integral == res[False].integral
    assert res[True].error == res[False].error
    assert res[True].n_evals == res[False].n_evals


def test_host_driver_parity_capacity_pressure():
    """An undersized store: overflow + forced finalise on the trajectory."""
    base = dict(d=3, integrand="f2", rel_tol=1e-10, capacity=1 << 7, max_iters=40)
    traj = {}
    res = {}
    for on in (True, False):
        traj[on] = []
        res[on] = integrate(
            QuadratureConfig(advance_window=on, **base),
            callback=lambda *a, t=traj[on]: t.append(a),
        )
    assert res[True].overflowed and res[False].overflowed
    assert res[True].status == res[False].status
    assert traj[True] == traj[False]
    assert res[True].integral == res[False].integral
    assert res[True].n_evals == res[False].n_evals


def test_device_driver_parity():
    base = dict(d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 12)
    w = integrate_device(QuadratureConfig(advance_window=True, **base))
    f = integrate_device(QuadratureConfig(advance_window=False, **base))
    assert w.status == f.status == "converged"
    assert w.iterations == f.iterations
    assert w.integral == f.integral
    assert w.error == f.error
    assert w.n_evals == f.n_evals


def test_distributed_driver_parity():
    # runs on however many devices are visible (1 in tier-1, 4 in CI)
    base = dict(d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 11, max_iters=100)
    w = integrate_distributed(QuadratureConfig(advance_window=True, **base))
    f = integrate_distributed(QuadratureConfig(advance_window=False, **base))
    assert w.status == f.status == "converged"
    assert w.iterations == f.iterations
    assert w.history == f.history
    assert w.integral == f.integral
    assert w.n_evals == f.n_evals


def test_batch_service_parity():
    from repro.core.integrands import get_param
    from repro.service.api import integrate_batch

    fam = get_param("genz_gaussian")
    rng = np.random.default_rng(3)
    thetas = [fam.sample_theta(2, rng) for _ in range(6)]
    base = dict(
        d=2, integrand="genz_gaussian", rel_tol=1e-6, capacity=1 << 9,
        batch_slots=4, max_iters=80,
    )
    out = {}
    for on in (True, False):
        cfg = QuadratureConfig(advance_window=on, **base)
        out[on] = [
            (r.req_id, r.integral, r.error, r.status, r.iterations, r.n_evals,
             r.admitted_at, r.finished_at)
            for r in integrate_batch(cfg, thetas, fam)
        ]
    assert out[True] == out[False]


def test_batch_service_parity_capacity_pressure():
    """Eviction regime: undersized stores overflow mid-fleet."""
    from repro.core.integrands import get_param
    from repro.service.api import integrate_batch

    fam = get_param("genz_gaussian")
    rng = np.random.default_rng(11)
    thetas = [fam.sample_theta(2, rng) for _ in range(6)]
    rels = [1e-9 if i == 0 else 1e-4 for i in range(6)]
    base = dict(
        d=2, integrand="genz_gaussian", capacity=1 << 7, batch_slots=4,
        max_iters=60,
    )
    out = {}
    for on in (True, False):
        cfg = QuadratureConfig(advance_window=on, **base)
        out[on] = [
            (r.req_id, r.integral, r.error, r.status, r.iterations, r.n_evals)
            for r in integrate_batch(cfg, thetas, fam, rel_tol=rels)
        ]
    assert any(r[3] == "capacity" for r in out[True])
    assert out[True] == out[False]


def test_config_knob_validates_and_defaults_on():
    assert QuadratureConfig(d=2).validate().advance_window is True
    cfg = QuadratureConfig(d=2, advance_window=False).validate()
    assert advance_ladder(cfg) == (cfg.capacity,)


def test_knob_combinations_all_agree():
    """eval_window and advance_window gate independent stages; every
    combination must walk the same trajectory."""
    base = dict(d=2, integrand="f2", rel_tol=1e-6, capacity=1 << 10, max_iters=100)
    outs = {}
    for ev in (True, False):
        for adv in (True, False):
            r = integrate(
                QuadratureConfig(eval_window=ev, advance_window=adv, **base)
            )
            outs[(ev, adv)] = (r.status, r.iterations, r.integral, r.error, r.n_evals)
    ref = outs[(False, False)]
    assert all(v == ref for v in outs.values()), outs
