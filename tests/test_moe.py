"""MoE dispatcher: sort-based capacity-bounded dispatch vs dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.moe import _capacity, moe_apply, moe_init, moe_ref_dense


def _cfg(**kw):
    base = dict(
        name="moe-test",
        family="moe",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=64,
        moe_experts=8,
        moe_top_k=2,
        moe_d_ff=64,
        dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_dispatch_matches_dense_oracle_when_capacity_unbounded():
    cfg = _cfg(capacity_factor=8.0)  # capacity >= T*k: nothing dropped
    params = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, metrics = moe_apply(cfg, params, x)
    ref = moe_ref_dense(cfg, params, x)
    assert float(metrics["dropped_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_shared_experts_added():
    cfg = _cfg(moe_shared_experts=1, capacity_factor=8.0)
    params = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out, _ = moe_apply(cfg, params, x)
    ref = moe_ref_dense(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_capacity_drops_are_bounded_and_flagged():
    cfg = _cfg(capacity_factor=0.5)  # force overflow
    params = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    out, metrics = moe_apply(cfg, params, x)
    assert float(metrics["dropped_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    cap = _capacity(cfg, 1024)
    # 1.25 * 1024 * 2 / 8 = 320
    assert cap == 320


def test_aux_loss_penalises_imbalance():
    cfg = _cfg(capacity_factor=8.0)
    params = moe_init(cfg, jax.random.PRNGKey(0))
    # biased router: all tokens to expert 0
    biased = dict(params)
    biased["router"] = params["router"].at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    _, m_uniform = moe_apply(cfg, params, x)
    _, m_biased = moe_apply(cfg, biased, x)
    assert float(m_biased["aux_loss"]) > float(m_uniform["aux_loss"])
