"""End-to-end single-device adaptive integration against analytic values."""

import numpy as np
import pytest

from repro.core import integrands
from repro.core.adaptive import integrate, integrate_device
from repro.core.config import QuadratureConfig
from repro.core.region_store import check_invariants, init_state, uniform_partition

CASES = [
    # (integrand, d, rel_tol, capacity)
    ("f1", 3, 1e-7, 1 << 15),
    ("f1", 5, 1e-6, 1 << 17),  # needs a large store: oscillatory, d=5
    ("f2", 3, 1e-6, 1 << 15),
    ("f3", 4, 1e-7, 1 << 15),
    ("f4", 3, 1e-7, 1 << 15),
    ("f4", 5, 1e-5, 1 << 15),
    ("f5", 3, 1e-5, 1 << 15),
    ("f6", 3, 1e-4, 1 << 15),
    ("f7", 4, 1e-7, 1 << 15),
]


@pytest.mark.parametrize("name,d,rel_tol,capacity", CASES)
def test_converges_to_exact(name, d, rel_tol, capacity):
    cfg = QuadratureConfig(
        d=d, integrand=name, rel_tol=rel_tol, capacity=capacity, max_iters=400
    )
    res = integrate(cfg)
    exact = integrands.get(name).exact(d)
    achieved = abs(res.integral - exact) / abs(exact)
    assert res.status == "converged", res.summary()
    # the requested tolerance must actually be met (paper Fig. 2b claim)
    assert achieved <= 5 * rel_tol, (res.summary(), achieved, exact)


def test_device_driver_matches_host_driver():
    cfg = QuadratureConfig(d=4, integrand="f4", rel_tol=1e-6, capacity=1 << 13)
    host = integrate(cfg)
    dev = integrate_device(cfg)
    assert dev.status == "converged"
    assert dev.integral == pytest.approx(host.integral, rel=1e-9)


def test_aggressive_mode_faster_on_peaked():
    # PAGANI-like pruning should use no more evaluations on the product peak.
    base = dict(d=3, integrand="f2", rel_tol=1e-6, capacity=1 << 14)
    robust = integrate(QuadratureConfig(classifier="robust", **base))
    aggressive = integrate(QuadratureConfig(classifier="aggressive", **base))
    assert aggressive.status == "converged"
    assert aggressive.n_evals <= robust.n_evals * 1.05


def test_capacity_feasibility_flag():
    # Tiny store at tight tolerance must hit capacity pressure (Fig. 3a).
    cfg = QuadratureConfig(
        d=5, integrand="f2", rel_tol=1e-9, capacity=256, n_init=8, max_iters=60
    )
    res = integrate(cfg)
    assert res.overflowed or res.status == "converged"


def test_uniform_partition_tiles_domain():
    lo, hi = np.zeros(3), np.ones(3)
    centers, halfw = uniform_partition(lo, hi, 16)
    assert centers.shape == (16, 3)
    vol = np.prod(2 * halfw, axis=1).sum()
    assert vol == pytest.approx(1.0, rel=1e-12)
    # boxes must be disjoint: pairwise L-inf separation >= sum of halfwidths
    for i in range(16):
        for j in range(i + 1, 16):
            gap = np.abs(centers[i] - centers[j]) - (halfw[i] + halfw[j])
            assert np.max(gap) >= -1e-12


def test_state_invariants_after_run():
    cfg = QuadratureConfig(d=3, integrand="f4", rel_tol=1e-5, capacity=1 << 12)
    # drive manually to keep the final state
    from repro.core.adaptive import make_advance_step, make_eval_step
    from repro.core.rules import make_rule
    import jax

    rule = make_rule(cfg)
    state = init_state(
        cfg.capacity, np.zeros(3), np.ones(3), cfg.resolved_n_init(), np.float64
    )
    ev = jax.jit(make_eval_step(cfg, rule))
    adv = jax.jit(make_advance_step(cfg, 1.0, np.ones(3)))
    for _ in range(8):
        state = ev(state)
        state = adv(state)
    check_invariants(state, np.zeros(3), np.ones(3))
    # total volume conservation: active + (finalised is not tracked by volume,
    # so only check actives are within the domain) — structural checks above.
