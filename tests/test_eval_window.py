"""Active-window evaluation: ladder selection + trajectory parity.

The windowed eval path must walk the *same* refinement trajectory as the
legacy full-capacity path — the compaction invariant guarantees every fresh
region sits inside the window, so the only difference is wasted work on dead
slots.  Parity is asserted per-iteration via the driver callback.
"""

import pytest

from repro.core.adaptive import integrate, integrate_device
from repro.core.config import QuadratureConfig
from repro.core.distributed import integrate_distributed
from repro.core.region_store import select_window, window_ladder


# --- bucket-ladder selection --------------------------------------------------


def test_window_ladder_geometric():
    lad = window_ladder(1 << 14, 256)
    assert lad[0] == 256
    assert lad[-1] == 1 << 14
    assert all(b == 2 * a for a, b in zip(lad, lad[1:]))


def test_window_ladder_min_clipped_to_capacity():
    assert window_ladder(128, 256) == (128,)
    assert window_ladder(1, 256) == (1,)


def test_window_ladder_rounds_min_up_to_power_of_two():
    assert window_ladder(1024, 100)[0] == 128


def test_window_ladder_rejects_non_power_of_two_capacity():
    with pytest.raises(ValueError):
        window_ladder(1000)


def test_select_window_edge_cases():
    lad = window_ladder(1 << 14, 256)
    assert select_window(lad, 0) == 256  # empty population -> cheapest rung
    assert select_window(lad, 1) == 256
    assert select_window(lad, 256) == 256  # exact rung
    assert select_window(lad, 257) == 512
    assert select_window(lad, 1000) == 1024  # non-power-of-two count
    assert select_window(lad, (1 << 14) - 1) == 1 << 14
    assert select_window(lad, 1 << 14) == 1 << 14  # full store


def test_host_and_device_rung_choice_agree():
    # the device path (make_switched_eval_step) picks the rung with a
    # left-searchsorted over the ladder; the host path uses select_window —
    # they must agree for every count or host/device trajectories diverge
    import jax.numpy as jnp

    lad = window_ladder(1 << 12, 256)
    rungs = jnp.asarray(lad, jnp.int32)
    for n in [0, 1, 255, 256, 257, 1000, 2047, 2048, 4095, 1 << 12]:
        ix = min(int(jnp.searchsorted(rungs, n)), len(lad) - 1)
        assert lad[ix] == select_window(lad, n)


def test_config_validates_window_knobs():
    with pytest.raises(ValueError):
        QuadratureConfig(d=2, eval_window_min=100).validate()
    with pytest.raises(ValueError):
        QuadratureConfig(d=2, sync_every=0).validate()
    with pytest.raises(ValueError):
        QuadratureConfig(d=2, block_regions=100).validate()


# --- trajectory parity --------------------------------------------------------

PARITY_CASES = [
    # (integrand, d, rule, rel_tol)
    ("f4", 3, "genz_malik", 1e-7),
    ("f2", 3, "genz_malik", 1e-6),
    ("f1", 2, "gauss_kronrod", 1e-8),
    ("f3", 3, "gauss_kronrod", 1e-7),
]


@pytest.mark.parametrize("name,d,rule,rel_tol", PARITY_CASES)
def test_windowed_matches_full_trajectory(name, d, rule, rel_tol):
    base = dict(
        d=d, integrand=name, rel_tol=rel_tol, capacity=1 << 13, rule=rule,
        max_iters=200,
    )
    traj_w, traj_f = [], []
    res_w = integrate(
        QuadratureConfig(eval_window=True, **base),
        callback=lambda *a: traj_w.append(a),
    )
    res_f = integrate(
        QuadratureConfig(eval_window=False, **base),
        callback=lambda *a: traj_f.append(a),
    )
    assert res_w.status == res_f.status
    assert res_w.iterations == res_f.iterations
    assert len(traj_w) == len(traj_f)
    for (it_w, i_w, e_w, n_w), (it_f, i_f, e_f, n_f) in zip(traj_w, traj_f):
        assert (it_w, n_w) == (it_f, n_f)
        assert i_w == pytest.approx(i_f, rel=1e-12)
        assert e_w == pytest.approx(e_f, rel=1e-12)
    assert res_w.integral == pytest.approx(res_f.integral, rel=1e-12)
    assert res_w.error == pytest.approx(res_f.error, rel=1e-12)
    assert res_w.n_evals == res_f.n_evals


def test_device_driver_windowed_matches_full():
    base = dict(d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 12)
    w = integrate_device(QuadratureConfig(eval_window=True, **base))
    f = integrate_device(QuadratureConfig(eval_window=False, **base))
    assert w.status == "converged"
    assert w.iterations == f.iterations
    assert w.integral == pytest.approx(f.integral, rel=1e-12)
    assert w.n_evals == f.n_evals


def test_windowed_kernel_path_matches_full():
    base = dict(
        d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 12, use_kernel=True
    )
    w = integrate(QuadratureConfig(eval_window=True, **base))
    f = integrate(QuadratureConfig(eval_window=False, **base))
    assert w.status == "converged"
    assert w.integral == pytest.approx(f.integral, rel=1e-12)
    assert w.n_evals == f.n_evals


def test_distributed_sync_every_parity():
    # single in-process device; the fused dispatch must replay the exact
    # per-iteration history that the K=1 host loop records
    base = dict(d=3, integrand="f4", rel_tol=1e-6, capacity=1 << 12, max_iters=100)
    r1 = integrate_distributed(QuadratureConfig(sync_every=1, **base))
    r4 = integrate_distributed(QuadratureConfig(sync_every=4, **base))
    assert r1.status == r4.status == "converged"
    assert r1.iterations == r4.iterations
    assert r1.history == r4.history
    assert r1.integral == pytest.approx(r4.integral, rel=1e-12)
