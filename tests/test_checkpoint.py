"""Checkpoint manager: atomic roundtrip, latest discovery, corruption, GC."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {
        "layer": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "count": jnp.asarray(seed, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3)
    mgr.save(3, tree, blocking=True)
    restored, step = mgr.restore(jax.tree.map(lambda x: x, tree))
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in (1, 2, 5):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.latest_step() == 5
    restored, step = mgr.restore(_tree(0))
    assert step == 5
    assert int(restored["count"]) == 5


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(1, 6):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [4, 5]


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1), blocking=True)
    # flip a crc in the manifest
    mpath = os.path.join(str(tmp_path), "step_00000001", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    first = next(iter(manifest["leaves"]))
    manifest["leaves"][first]["crc32"] ^= 0xDEADBEEF
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IOError):
        mgr.restore(_tree(0))


def test_shape_mismatch_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(1), blocking=True)
    bad = {
        "layer": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))},
        "count": jnp.asarray(0, jnp.int32),
    }
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_train_resume_equivalence(tmp_path):
    """Train 4 steps == train 2, checkpoint, restore, train 2 more."""
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataConfig, batch_for_step
    from repro.models.model import model_init
    from repro.training.optimizer import OptimizerConfig, init_opt_state
    from repro.training.train import TrainConfig, make_train_step

    cfg = get_smoke_config("deepseek-7b")
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=32, seed=7)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, s).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    params = model_init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(tcfg.opt, params)
    p4, o4 = run(params, opt, 0, 4)

    p2, o2 = run(params, opt, 0, 2)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"params": p2, "opt": o2}, blocking=True)
    restored, _ = mgr.restore({"params": p2, "opt": o2})
    p_res, o_res = run(restored["params"], restored["opt"], 2, 4)

    for a, b in zip(jax.tree.leaves(p4), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
