"""Sharded batch service: device-count parity + CLI validation.

Runs ``repro.service.sharded_selftest`` in a subprocess so that
``--xla_force_host_platform_device_count`` can take effect (the main pytest
process has already initialised jax with a single device).  The selftest
itself asserts bit-identical ``QuadResult``\\ s across 1/2/4-device meshes —
these tests re-check the reported summary and pin the scenario coverage.
"""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(module, *args, env_extra=None, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-m", module, *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=_REPO,
        env=env,
    )


@pytest.fixture(scope="module")
def selftest_output():
    proc = _run("repro.service.sharded_selftest", "4")
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    assert line, proc.stdout[-4000:]
    return json.loads(line[-1][len("RESULT_JSON:") :])


def test_parity_across_1_2_4_devices(selftest_output):
    assert selftest_output["n_devices"] == 4
    assert selftest_output["device_counts"] == [1, 2, 4]
    for name, case in selftest_output["cases"].items():
        assert case["parity"], name


def test_every_terminal_status_is_covered(selftest_output):
    cases = selftest_output["cases"]
    assert cases["converged_midflight"]["statuses"] == ["converged"]
    assert "capacity" in cases["evicted"]["statuses"]  # store-saturation evict
    assert cases["max_iters"]["statuses"] == ["max_iters"]


def test_midflight_admission_exercised(selftest_output):
    assert selftest_output["cases"]["converged_midflight"]["midflight_admissions"] > 0


def test_problem_migration_fires_on_real_rings(selftest_output):
    migrations = selftest_output["cases"]["rebalanced"]["migrations"]
    assert migrations["1"] == 0  # nothing to pair with
    assert migrations["2"] > 0 and migrations["4"] > 0, migrations


def test_recorder_replay_keeps_parity_and_traces_migrations(selftest_output):
    """The selftest re-runs the drain-heavy case with a Recorder attached:
    bit-parity with the recorder-off run (4 devices), >=1 migration flow in
    a structurally valid Chrome trace, and an idle-fraction timeline that
    matches the fig-4b formula recomputed from the raw gauge events."""
    tel = selftest_output["cases"]["rebalanced"]["telemetry"]
    assert tel["parity"] and tel["trace_check"] == "ok"
    assert tel["devices"] == 4
    assert tel["migration_flows"] > 0
    assert len(tel["idle_fraction"]) == 4
    assert all(0.0 <= f < 1.0 for f in tel["idle_fraction"])


# --- CLI fail-fast validation (launch.serve_quad) ------------------------------


def test_cli_rejects_oversized_batch_slots():
    """--batch-slots beyond what the region store's memory allows must fail
    fast with an actionable message, not die inside XLA allocation."""
    proc = _run(
        "repro.launch.serve_quad",
        "--batch-slots", str(1 << 22),
        "--capacity", str(1 << 12),
        "--n-requests", "1",
    )
    assert proc.returncode != 0
    assert "--batch-slots" in proc.stderr and "GiB" in proc.stderr, proc.stderr[-2000:]
    assert "Traceback" not in proc.stderr, proc.stderr[-2000:]


def test_cli_rejects_indivisible_batch_slots_per_device():
    proc = _run(
        "repro.launch.serve_quad",
        "--batch-slots", "10",
        "--devices", "4",
        "--n-requests", "1",
        env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
    )
    assert proc.returncode != 0
    assert "multiple of" in proc.stderr, proc.stderr[-2000:]
    assert "Traceback" not in proc.stderr, proc.stderr[-2000:]


def test_cli_rejects_more_devices_than_visible():
    proc = _run(
        "repro.launch.serve_quad",
        "--devices", "64",
        "--batch-slots", "64",
        "--n-requests", "1",
    )
    assert proc.returncode != 0
    assert "devices" in proc.stderr, proc.stderr[-2000:]
    assert "Traceback" not in proc.stderr, proc.stderr[-2000:]
