"""Telemetry subsystem: recorder semantics, sinks, trace export, load views.

Pure-Python units (fake clock, hand-built event streams) plus the two
integration invariants the subsystem is built around:

- recorder-on/off **bit-parity**: attaching a Recorder to the batch service
  changes no result bit (host-side recording at dispatch boundaries only;
  the 4-device variant of this assertion lives in
  ``repro.service.sharded_selftest`` via ``test_sharded_service.py``);
- the live-telemetry imbalance equals the offline
  ``DistributedResult.mean_imbalance()`` on the same run — the fig-4b
  number is one computation, whichever path reports it.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.config import QuadratureConfig
from repro.core.integrands import get_param
from repro.service import BatchScheduler, GracefulScheduler, QuadRequest
from repro.telemetry import (
    NULL,
    JsonlSink,
    MemorySink,
    Recorder,
    ServiceStats,
    read_jsonl,
    summary_table,
    to_chrome,
    write_chrome_trace,
)
from repro.telemetry import loadview
from repro.telemetry.check import check_metrics, check_trace

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAMILY = get_param("genz_gaussian")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# --- recorder core -----------------------------------------------------------


def test_span_nesting_ordering_and_durations():
    clock = FakeClock()
    sink = MemorySink()
    rec = Recorder(sinks=(sink,), clock=clock)
    with rec.span("outer", lane=None) as outer:
        clock.advance(1.0)
        with rec.span("inner", lane=2, it=7):
            clock.advance(0.5)
        clock.advance(0.25)
        outer["executed"] = 3
    kinds = [(e["kind"], e["name"]) for e in sink.events]
    assert kinds == [
        ("span_begin", "outer"),
        ("span_begin", "inner"),
        ("span_end", "inner"),
        ("span_end", "outer"),
    ]
    begin_outer, begin_inner, end_inner, end_outer = sink.events
    assert begin_outer["depth"] == 0 and begin_inner["depth"] == 1
    assert end_inner["dur"] == 0.5 and end_inner["it"] == 7
    assert end_inner["lane"] == 2
    assert end_outer["dur"] == 1.75
    assert end_outer["executed"] == 3  # body-added attr rides on span_end
    assert [e["seq"] for e in sink.events] == [0, 1, 2, 3]
    # aggregates for the summary table
    assert rec.span_totals["outer"] == {"count": 1, "total_s": 1.75}


def test_counters_gauges_hists_aggregate():
    rec = Recorder(sinks=(MemorySink(),), clock=FakeClock())
    rec.count("service.admissions", 2)
    rec.count("service.admissions")
    rec.gauge("service.n_live", 5, lane=1)
    rec.gauge("service.n_live", 3, lane=1)
    rec.observe("dispatch_ms", 4.0)
    rec.observe("dispatch_ms", 6.0)
    assert rec.counters["service.admissions"] == 3
    assert rec.gauges["service.n_live[1]"] == 3  # last write wins
    assert rec.hists["dispatch_ms"] == {
        "count": 2,
        "sum": 10.0,
        "min": 4.0,
        "max": 6.0,
    }
    table = summary_table(rec)
    assert "service.admissions" in table and "dispatch_ms" in table


def test_null_recorder_is_inert():
    assert not NULL.enabled
    NULL.count("x")
    NULL.gauge("x", 1)
    with NULL.span("x") as sp:
        sp["attr"] = 1  # swallowed, not an error
    assert NULL.flow("x", 0, 1) == 0
    with pytest.raises(RuntimeError):
        NULL.add_sink(MemorySink())


# --- sinks -------------------------------------------------------------------


def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    rec = Recorder(sinks=(JsonlSink(path),), clock=FakeClock())
    rec.count("a", 1)
    rec.gauge("b", np.float64(2.5), lane=np.int32(1))  # numpy scalars tolerated
    rec.event("c", note="hi")
    rec.close()
    events = read_jsonl(path)
    assert [e["kind"] for e in events] == ["counter", "gauge", "instant"]
    assert events[1]["value"] == 2.5 and events[1]["lane"] == 1
    assert events[2]["note"] == "hi"
    assert check_metrics(path) == []


# --- chrome trace export -----------------------------------------------------


def _synthetic_run_events():
    clock = FakeClock()
    sink = MemorySink()
    rec = Recorder(sinks=(sink,), clock=clock)
    rec.event("service.start", backend="cubature")
    for it in range(3):
        with rec.span("service.dispatch", it=it):
            clock.advance(0.01)
        for dev in range(2):
            rec.gauge("service.n_live", 2 - dev, lane=dev, it=it + 1)
    rec.flow("service.migrate", 0, 1, req_id=5)
    rec.count("service.iterations", 3)
    return sink.events


def test_chrome_trace_schema_valid(tmp_path):
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, _synthetic_run_events())
    assert check_trace(path, n_devices=2, expect_flow=True) == []
    doc = json.load(open(path))
    events = doc["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "B", "E", "i", "C", "s", "f"} <= phases
    for e in events:
        assert "pid" in e and "tid" in e
        if e["ph"] != "M":
            assert "ts" in e
    # balanced B/E per lane
    opens = {}
    for e in events:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            opens[key] = opens.get(key, 0) + 1
        elif e["ph"] == "E":
            opens[key] -= 1
    assert all(v == 0 for v in opens.values()), opens


def test_chrome_trace_checker_flags_problems(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump(
            {
                "traceEvents": [
                    {"ph": "B", "name": "x", "pid": 1, "tid": 1, "ts": 0}
                ]
            },
            f,
        )
    problems = check_trace(path, n_devices=1, expect_flow=True)
    assert any("unclosed" in p for p in problems)
    assert any("device 0" in p for p in problems)
    assert any("flow" in p for p in problems)


def test_checker_expect_flow_name(tmp_path):
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, _synthetic_run_events())
    assert check_trace(path, expect_flow_name="service.migrate") == []
    problems = check_trace(path, expect_flow_name="service.evacuate")
    assert any("service.evacuate" in p for p in problems), problems


def test_attrs_cannot_clobber_event_envelope():
    """Regression: rec.flow(..., kind="readmit") once overwrote the event's
    own "kind" field, silently turning both flow halves into unknown-typed
    events every consumer dropped.  The envelope must win for all emitters."""
    sink = MemorySink()
    rec = Recorder(sinks=(sink,), clock=FakeClock())
    rec.count("c", kind="evil", ts=99)
    rec.gauge("g", 1.0, kind="evil")
    rec.observe("h", 1.0, kind="evil")
    rec.event("i", kind="evil")
    with rec.span("s", kind="evil") as sp:
        sp["kind"] = "evil"  # body attrs ride span_end, envelope still wins
    rec.flow("f", 0, 1, kind="evil", id=-1)
    kinds = [e["kind"] for e in sink.events]
    assert kinds == [
        "counter",
        "gauge",
        "hist",
        "instant",
        "span_begin",
        "span_end",
        "flow_begin",
        "flow_end",
    ]
    assert [e["name"] for e in sink.events][:1] == ["c"]
    assert sink.events[0]["ts"] == 0.0
    assert sink.events[-1]["id"] == sink.events[-2]["id"] == 1


# --- load views --------------------------------------------------------------


def test_imbalance_matches_dist_step_formula():
    assert loadview.imbalance([4, 4, 4, 4]) == 0.0
    assert loadview.imbalance([8, 0, 0, 0]) == pytest.approx(1 - 2 / 8)
    assert loadview.imbalance([0, 0]) == 0.0  # all-idle iteration
    assert loadview.imbalance([]) == 0.0


def test_idle_fraction_on_hand_built_timeline():
    # 2 devices x 3 iterations, 4 slots per device
    events = []
    series = {0: [4, 4, 2], 1: [4, 0, 0]}
    for it in range(3):
        for dev in (0, 1):
            events.append(
                {
                    "kind": "gauge",
                    "name": "service.n_live",
                    "ts": float(it),
                    "seq": len(events),
                    "lane": dev,
                    "value": series[dev][it],
                    "it": it,
                }
            )
    tl = loadview.occupancy_from_events(events)
    assert tl.devices == [0, 1] and tl.iterations == [0, 1, 2]
    assert tl.series(0) == [4, 4, 2] and tl.series(1) == [4, 0, 0]
    idle = loadview.idle_fraction(tl, slots_per_device=4)
    assert idle[0] == pytest.approx(1 - 10 / 12)
    assert idle[1] == pytest.approx(1 - 4 / 12)
    imb = loadview.imbalance_series(tl)
    assert imb[0] == 0.0
    assert imb[1] == pytest.approx(1 - 2 / 4)
    assert loadview.mean_imbalance(tl) == pytest.approx(sum(imb) / 3)


# --- ServiceStats ------------------------------------------------------------


def test_service_stats_add_merge_round_trip():
    a = ServiceStats()
    a.add("admissions", 3)
    a.add("migrations")
    b = ServiceStats(iterations=5, admissions=1)
    a.merge(b)
    assert a.admissions == 4 and a.iterations == 5 and a.migrations == 1
    assert ServiceStats.from_dict(a.as_dict()) == a


def test_service_stats_drift_guard():
    # missing keys default (old snapshots), unknown keys are loud (drift)
    assert ServiceStats.from_dict({"admissions": 2}).admissions == 2
    with pytest.raises(ValueError, match="frobnications"):
        ServiceStats.from_dict({"frobnications": 1})
    with pytest.raises(AttributeError):
        ServiceStats().add("frobnications")


def test_service_stats_elastic_counters_in_schema():
    """The device-loss counters are first-class schema fields: they round-trip
    through from_dict (so GracefulScheduler's field-wise merge aggregates
    them) and appear in every pool's stats dict."""
    s = ServiceStats.from_dict(
        {
            "dispatch_retries": 2,
            "evacuations": 4,
            "mesh_shrinks": 1,
            "mesh_regrows": 1,
        }
    )
    assert (s.dispatch_retries, s.evacuations, s.mesh_shrinks, s.mesh_regrows) == (
        2,
        4,
        1,
        1,
    )
    merged = ServiceStats()
    merged.merge(s)
    merged.merge(s)
    assert merged.evacuations == 8 and merged.mesh_shrinks == 2
    assert {"dispatch_retries", "evacuations", "mesh_shrinks", "mesh_regrows"} <= set(
        s.as_dict()
    )


# --- bit-parity: recorder on vs off ------------------------------------------


def _cfg(**kw):
    base = dict(
        d=2,
        integrand="genz_gaussian",
        rel_tol=1e-4,
        capacity=1 << 9,
        batch_slots=4,
        max_iters=60,
        sync_every=4,
    )
    base.update(kw)
    return QuadratureConfig(**base)


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        QuadRequest(req_id=i, theta=FAMILY.sample_theta(2, rng))
        for i in range(n)
    ]


def _tuples(results):
    return [
        (
            r.req_id,
            float(r.integral).hex(),
            float(r.error).hex(),
            r.status,
            r.iterations,
            r.n_evals,
            r.admitted_at,
            r.finished_at,
        )
        for r in sorted(results, key=lambda r: r.req_id)
    ]


def test_recorder_on_off_bit_parity_single_device():
    off = list(BatchScheduler(_cfg(), FAMILY).serve(_requests(6)))
    rec = Recorder(sinks=(MemorySink(),))
    on = list(BatchScheduler(_cfg(), FAMILY, recorder=rec).serve(_requests(6)))
    assert _tuples(on) == _tuples(off)
    assert rec.counters["service.collections"] == 6


def test_graceful_recorder_parity_and_stats_view():
    off_sched = GracefulScheduler(_cfg(), FAMILY)
    off = list(off_sched.serve(_requests(5)))
    sink = MemorySink()
    on_sched = GracefulScheduler(_cfg(), FAMILY, recorder=Recorder(sinks=(sink,)))
    on = list(on_sched.serve(_requests(5)))
    assert _tuples(on) == _tuples(off)
    assert on_sched.last_stats == off_sched.last_stats  # compat dict view
    assert set(on_sched.last_stats) == {
        f.name for f in __import__("dataclasses").fields(ServiceStats)
    }
    assert any(e["name"] == "service.drain" for e in sink.events)


def test_scheduler_records_per_device_occupancy():
    sink = MemorySink()
    sched = BatchScheduler(_cfg(), FAMILY, recorder=Recorder(sinks=(sink,)))
    list(sched.serve(_requests(6)))
    tl = loadview.occupancy_from_events(sink.events)
    assert tl.devices == [0]  # single-device pytest process
    assert len(tl.iterations) > 0
    assert max(tl.series(0)) <= 4  # never exceeds slots per device
    idle = loadview.idle_fraction(tl, slots_per_device=4)
    assert 0.0 <= idle[0] < 1.0


# --- distributed imbalance: live telemetry == offline statistic --------------


def test_distributed_imbalance_telemetry_matches_offline():
    """The fig-4b number is one computation: the mean of the recorded
    ``dist.work_imb`` gauges must equal ``DistributedResult.mean_imbalance()``
    on the same run (2 virtual devices, subprocess so XLA_FLAGS applies)."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = ('--xla_force_host_platform_device_count=2 '"
        " + os.environ.get('XLA_FLAGS', ''))\n"
        "import jax, json\n"
        "jax.config.update('jax_enable_x64', True)\n"
        "from repro.core.config import QuadratureConfig\n"
        "from repro.core.distributed import integrate_distributed\n"
        "from repro.telemetry import MemorySink, Recorder\n"
        "from repro.telemetry.loadview import mean_work_imbalance_from_events\n"
        "sink = MemorySink()\n"
        "cfg = QuadratureConfig(d=3, integrand='f6', rel_tol=1e-5,"
        " capacity=1 << 12, max_iters=100)\n"
        "res = integrate_distributed(cfg, recorder=Recorder(sinks=(sink,)))\n"
        "print('RESULT_JSON:' + json.dumps({\n"
        "    'offline': res.mean_imbalance(),\n"
        "    'telemetry': mean_work_imbalance_from_events(sink.events),\n"
        "    'n': len(res.history), 'status': res.status}))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=600,
        cwd=_REPO,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    out = json.loads(line[-1][len("RESULT_JSON:") :])
    assert out["n"] > 0 and out["status"] == "converged"
    # np.mean (pairwise) vs pure-python mean (sequential): identical values,
    # summation order may differ in the last ulp
    assert out["telemetry"] == pytest.approx(out["offline"], rel=1e-12, abs=1e-15)


# --- histogram quantiles + scheduler dispatch-latency histograms -------------


def test_quantile_function():
    from repro.telemetry import quantile

    assert quantile([], 0.5) == 0.0
    assert quantile([3.0], 0.0) == quantile([3.0], 1.0) == 3.0
    vals = [4.0, 1.0, 3.0, 2.0]
    assert quantile(vals, 0.5) == 2.5  # linear interpolation, order-free
    assert quantile(vals, 0.0) == 1.0 and quantile(vals, 1.0) == 4.0
    assert quantile(vals, 0.25) == 1.75
    with pytest.raises(ValueError):
        quantile(vals, 1.5)


def test_recorder_hist_quantiles_and_summary_columns():
    rec = Recorder(sinks=(MemorySink(),), clock=FakeClock())
    for v in (1.0, 2.0, 3.0, 4.0, 100.0):
        rec.observe("lat_s", v)
    assert rec.quantile("lat_s", 0.5) == 3.0
    assert rec.quantile("missing", 0.5) == 0.0
    qs = rec.hist_quantiles("lat_s")
    assert set(qs) == {0.5, 0.99} and qs[0.99] > qs[0.5]
    table = summary_table(rec)
    assert "p50" in table and "p99" in table
    # NullRecorder mirrors the API inertly
    assert NULL.quantile("lat_s", 0.5) == 0.0
    assert NULL.hist_quantiles("lat_s") == {0.5: 0.0, 0.99: 0.0}


def test_hist_sample_cap_keeps_aggregates_exact():
    from repro.telemetry.core import HIST_SAMPLE_CAP

    rec = Recorder(sinks=(), clock=FakeClock())
    n = HIST_SAMPLE_CAP + 100
    for i in range(n):
        rec.observe("x", float(i))
    # aggregates see every value; the quantile sample is the first N
    assert rec.hists["x"]["count"] == n
    assert rec.hists["x"]["max"] == float(n - 1)
    assert len(rec.hist_samples["x"]) == HIST_SAMPLE_CAP
    assert rec.quantile("x", 1.0) == float(HIST_SAMPLE_CAP - 1)


def test_scheduler_records_dispatch_latency_histograms():
    sink = MemorySink()
    rec = Recorder(sinks=(sink,))
    list(BatchScheduler(_cfg(), FAMILY, recorder=rec).serve(_requests(6)))
    # one wall-time sample per executed dispatch, recorded host-side at the
    # dispatch boundary (DESIGN.md §9); queue-wait has one fewer sample
    # (it measures the gap since the *previous* dispatch)
    wall = loadview.hist_values_from_events(sink.events, "service.dispatch_wall_s")
    wait = loadview.hist_values_from_events(sink.events, "service.queue_wait_s")
    n_dispatch = rec.counters["service.dispatches"]
    assert len(wall) == n_dispatch > 0
    assert len(wait) == n_dispatch - 1
    assert all(v > 0 for v in wall)
    assert all(v >= 0 for v in wait)
    # live aggregates match the event stream (same samples, same math)
    from repro.telemetry import quantile

    assert rec.quantile("service.dispatch_wall_s", 0.5) == quantile(wall, 0.5)
    assert rec.hists["service.dispatch_wall_s"]["count"] == len(wall)
