"""Per-architecture smoke tests: reduced config, one forward + one train step.

Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); here every family runs REAL numerics on CPU: output shapes,
finiteness, and a loss that responds to a train step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.synthetic import DataConfig, batch_for_step, frame_batch_for_step
from repro.models.model import model_forward, model_init
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train import TrainConfig, lm_loss, make_train_step

B, S = 2, 64


def _batch_for(cfg):
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=B, seq_len=S, seed=1)
    if cfg.family == "audio":
        return frame_batch_for_step(dc, 0, cfg.d_model)
    if cfg.family == "vlm":
        nf = cfg.n_frontend_tokens
        tok = batch_for_step(
            DataConfig(vocab_size=cfg.vocab_size, batch=B, seq_len=S - nf, seed=1), 0
        )
        rng = np.random.default_rng(0)
        embeds = rng.standard_normal((B, nf, cfg.d_model)).astype(np.float32)
        labels = np.concatenate(
            [np.zeros((B, nf), np.int32), tok["labels"]], axis=1
        )
        mask = np.concatenate(
            [np.zeros((B, nf), np.float32), np.ones_like(tok["labels"], np.float32)],
            axis=1,
        )
        return {
            "tokens": tok["tokens"],
            "embeds": embeds,
            "labels": labels,
            "loss_mask": mask,
        }
    return batch_for_step(dc, 0)


@pytest.fixture(scope="module")
def smoke_cache():
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(
        lambda p, b: model_forward(
            cfg, p, tokens=b.get("tokens"), embeds=b.get("embeds")
        )
    )(params, {k: jnp.asarray(v) for k, v in batch.items()})
    s_out = batch["labels"].shape[1]
    assert logits.shape == (B, s_out, cfg.vocab_size), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = get_smoke_config(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptimizerConfig(lr=5e-3, warmup_steps=1, total_steps=50))
    opt_state = init_opt_state(tcfg.opt, params)
    step = jax.jit(make_train_step(cfg, tcfg))

    losses = []
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in _batch_for(cfg).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["ce_loss"]))
        assert np.isfinite(losses[-1]), (arch, losses)
    # same (deterministic) batch every step -> the loss must drop
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_sane(arch):
    """The analytic parameter count must be in the ballpark of the name."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "mamba2-370m": (0.25e9, 0.6e9),
        "deepseek-7b": (5e9, 9e9),
        "minitron-4b": (3e9, 6e9),
        "mistral-nemo-12b": (10e9, 15e9),
        "qwen3-32b": (28e9, 38e9),
        "jamba-v0.1-52b": (40e9, 60e9),
        "internvl2-2b": (1.4e9, 2.6e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, f"{n/1e9:.2f}B")


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 18e9 <= active <= 26e9, f"{active/1e9:.2f}B"  # "A22B"
    cfg2 = get_config("deepseek-v2-236b")
    active2 = cfg2.active_param_count()
    assert 15e9 <= active2 <= 27e9, f"{active2/1e9:.2f}B"  # "21B active"
