"""Batch quadrature service: engine parity, continuous batching, registry."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from repro.core import QuadratureConfig, integrate
from repro.core.integrands import bind, from_spec, get, get_param
from repro.service import (
    BatchEngine,
    BatchScheduler,
    QuadRequest,
    integrate_batch,
    serve,
)

FAMILY = get_param("genz_gaussian")
D = 3


def _cfg(**kw):
    base = dict(
        d=D,
        integrand="genz_gaussian",
        rel_tol=1e-6,
        capacity=1 << 11,
        batch_slots=4,
        max_iters=120,
    )
    base.update(kw)
    return QuadratureConfig(**base)


def _thetas(n, seed=0, d=D):
    rng = np.random.default_rng(seed)
    return [FAMILY.sample_theta(d, rng) for _ in range(n)]


# --- parity: the acceptance-criterion test -----------------------------------


def test_batch_matches_serial_and_exact_with_midflight_admission():
    """Every QuadResult matches the serial `integrate` run for the same theta
    and the analytic exact value within its requested tolerance — including
    slots admitted mid-flight after another slot converged."""
    cfg = _cfg()
    thetas = _thetas(10)
    results = integrate_batch(cfg, thetas)
    assert [r.req_id for r in results] == list(range(10))
    admitted = {r.admitted_at for r in results}
    assert len(admitted) > 1, "fleet fit in one wave; no mid-flight admission"
    for theta, res in zip(thetas, results):
        assert res.status == "converged"
        exact = FAMILY.exact(D, theta)
        serial = integrate(cfg, bind(FAMILY, theta).fn)
        # engine and serial driver share eval/classify/split code on the same
        # window ladder → identical refinement trajectories, not just close
        assert res.integral == pytest.approx(serial.integral, rel=1e-13, abs=0)
        assert res.iterations == serial.iterations
        budget = max(cfg.abs_tol, abs(exact) * cfg.rel_tol)
        # claimed error bound is satisfied and honest w.r.t. the true error
        assert res.error <= budget
        assert abs(res.integral - exact) <= 10 * max(res.error, budget)


def test_midflight_slot_is_bitwise_identical_to_serial():
    """A slot refilled mid-flight reuses a store left stale by the previous
    occupant; the fresh write must make its trajectory indistinguishable
    from a cold start."""
    cfg = _cfg(batch_slots=2)
    thetas = _thetas(5, seed=7)
    results = integrate_batch(cfg, thetas)
    late = [r for r in results if r.admitted_at > 0]
    assert late, "no slot was refilled mid-flight"
    for res in late:
        serial = integrate(cfg, bind(FAMILY, thetas[res.req_id]).fn)
        assert res.integral == serial.integral
        assert res.iterations == serial.iterations


def test_max_iters_parity_with_serial():
    """The iteration cap must fire after the same number of eval sweeps as
    the serial driver: same integral, error, eval count, and iteration
    count (regression: the engine used to run one extra sweep)."""
    cfg = _cfg(batch_slots=2, max_iters=6, rel_tol=1e-14)
    theta = _thetas(1, seed=29)[0]
    (res,) = integrate_batch(cfg, [theta])
    serial = integrate(cfg, bind(FAMILY, theta).fn)
    assert serial.status == "max_iters"  # guard: the cap path is exercised
    assert res.status == "max_iters"
    assert res.integral == serial.integral
    assert res.error == serial.error
    assert res.n_evals == serial.n_evals
    assert res.iterations == serial.iterations


# --- tolerances, ordering, input shapes --------------------------------------


def test_per_request_tolerances():
    cfg = _cfg(batch_slots=2)
    theta = _thetas(1, seed=3)[0]
    loose, tight = integrate_batch(
        cfg, [theta, theta], rel_tol=[1e-3, 1e-6]
    )
    assert loose.status == tight.status == "converged"
    assert loose.iterations < tight.iterations
    assert loose.n_evals < tight.n_evals
    exact = FAMILY.exact(D, theta)
    assert abs(tight.integral - exact) <= abs(exact) * 1e-4


def test_per_request_tolerance_parity_aggressive_classifier():
    """The aggressive classifier's local-prune term uses rel_tol directly,
    so it must see the request's tolerance, not cfg's (regression: it used
    to read cfg.rel_tol and silently change the refinement trajectory)."""
    import dataclasses as dc

    cfg = _cfg(batch_slots=2, classifier="aggressive", rel_tol=1e-8)
    theta = _thetas(1, seed=3)[0]
    (res,) = integrate_batch(cfg, [theta], rel_tol=1e-3)
    serial = integrate(dc.replace(cfg, rel_tol=1e-3), bind(FAMILY, theta).fn)
    assert res.integral == serial.integral
    assert res.n_evals == serial.n_evals
    assert res.iterations == serial.iterations


def test_engine_accepts_kernel_path():
    """Families run on the fused kernel path (theta rides as a kernel
    operand, see kernels.ops) — the old captured-constant rejection is gone.
    Full kernel-vs-serial bit parity lives in tests/test_kernels.py."""
    engine = BatchEngine(_cfg(use_kernel=True, batch_slots=2))
    assert engine.cfg.use_kernel
    res = integrate(
        QuadratureConfig(
            d=2,
            integrand="genz_gaussian:5,5:0.3,0.7",
            use_kernel=True,
            rel_tol=1e-5,
            capacity=1 << 9,
        )
    )
    assert res.status == "converged"


def test_stacked_theta_pytree():
    cfg = _cfg(batch_slots=3)
    thetas = _thetas(3, seed=5)
    stacked = {
        k: np.stack([t[k] for t in thetas]) for k in FAMILY.theta_fields
    }
    a = integrate_batch(cfg, thetas)
    b = integrate_batch(cfg, stacked)
    assert [r.integral for r in a] == [r.integral for r in b]


def test_serve_streams_in_convergence_order():
    cfg = _cfg(batch_slots=4, rel_tol=1e-5)
    thetas = _thetas(6, seed=11)
    reqs = (QuadRequest(req_id=i, theta=t) for i, t in enumerate(thetas))
    seen = []
    for res in serve(cfg, reqs, FAMILY):  # generator input: lazy pull
        seen.append(res)
        assert res.finished_at >= res.admitted_at
    assert sorted(r.req_id for r in seen) == list(range(6))
    assert [r.finished_at for r in seen] == sorted(r.finished_at for r in seen)


def test_admit_every_batches_admissions():
    # request 0 is tight enough to keep one slot busy for the whole run, so
    # while it is in flight every admission must land on the admit_every
    # cadence (once the fleet fully drains, immediate refill is allowed)
    cfg = _cfg(batch_slots=2, admit_every=5, rel_tol=1e-3)
    thetas = _thetas(6, seed=13)
    results = integrate_batch(cfg, thetas, rel_tol=[1e-6] + [1e-3] * 5)
    assert all(r.status == "converged" for r in results)
    anchor_end = results[0].finished_at
    for r in results[1:]:
        if 0 < r.admitted_at <= anchor_end:
            assert r.admitted_at % 5 == 0, (r.req_id, r.admitted_at)
    assert any(
        0 < r.admitted_at <= anchor_end for r in results[1:]
    ), "no admission happened while the anchor request was in flight"


# --- eviction: capacity-overflow slots don't wedge the fleet -----------------


def test_capacity_overflow_is_evicted_and_queue_drains():
    # request 0 asks for 1e-8 from a 128-slot store — the population
    # saturates before converging, so the engine freezes the slot and the
    # scheduler evicts it with status "capacity" while the easy requests
    # keep flowing through the freed capacity
    cfg = _cfg(capacity=1 << 7, batch_slots=2, rel_tol=1e-4, max_iters=80)
    hard = _thetas(1, seed=3)[0]
    easy = _thetas(4, seed=17)
    results = integrate_batch(
        cfg, [hard] + easy, rel_tol=[1e-8] + [1e-4] * 4
    )
    assert results[0].status == "capacity"
    assert all(r.status == "converged" for r in results[1:])
    # best-effort estimate at eviction time is still in the right ballpark
    exact = FAMILY.exact(D, hard)
    assert abs(results[0].integral - exact) <= 0.1 * abs(exact)


# --- engine-level unit tests -------------------------------------------------


def test_engine_theta_shape_validation():
    eng = BatchEngine(_cfg())
    state = eng.init()
    with pytest.raises(ValueError, match="theta shape mismatch"):
        eng.admit(state, 0, {"a": np.zeros(D + 1), "u": np.zeros(D + 1)})


def test_engine_step_on_empty_fleet_is_noop():
    eng = BatchEngine(_cfg())
    state = eng.init()
    state, metrics = eng.step(state)
    assert not bool(np.any(np.asarray(metrics["done"])))
    assert not bool(np.any(np.asarray(metrics["occupied"])))
    assert int(np.asarray(metrics["n_active"]).sum()) == 0


def test_scheduler_empty_request_stream():
    assert list(BatchScheduler(_cfg()).serve([])) == []


# --- parameterized-integrand registry (satellite) ----------------------------


def test_from_spec_round_trip():
    spec = "genz_gaussian:5,5:0.3,0.7"
    integrand = from_spec(spec)
    ref = FAMILY.exact(2, {"a": np.array([5.0, 5.0]), "u": np.array([0.3, 0.7])})
    assert integrand.exact(2) == pytest.approx(ref, rel=1e-15)
    assert get(spec).exact(2) == integrand.exact(2)  # reachable through get()


@pytest.mark.parametrize(
    "spec",
    [
        "genz_gaussian",  # missing theta groups
        "genz_gaussian:1,2",  # one group, needs two
        "genz_gaussian:1,2:0.5",  # unequal group lengths
        "genz_gaussian:a,b:c,d",  # non-numeric
        "nosuchfamily:1,2",
    ],
)
def test_from_spec_rejects_malformed(spec):
    with pytest.raises((KeyError, ValueError)):
        from_spec(spec)


def test_spec_theta_length_must_match_d():
    """A spec whose theta length disagrees with d must raise, not silently
    broadcast in the integrand while exact() truncates (regression)."""
    integrand = from_spec("monomial:2")  # length-1 theta
    with pytest.raises(ValueError, match="length 1"):
        integrand.exact(3)
    cfg = QuadratureConfig(d=3, integrand="monomial:2", capacity=1 << 10)
    with pytest.raises(ValueError, match="theta leaf"):
        integrate(cfg)


def test_config_can_name_family_spec():
    """QuadratureConfig.integrand can carry a family spec end to end."""
    cfg = QuadratureConfig(
        d=2, integrand="monomial:2,3", rel_tol=1e-10, capacity=1 << 10
    )
    res = integrate(cfg)
    assert res.integral == pytest.approx(1.0 / 3.0 / 4.0, rel=1e-9)


@pytest.mark.parametrize("name", ["genz_gaussian", "genz_product_peak", "monomial"])
def test_family_exact_against_serial(name):
    fam = get_param(name)
    theta = fam.sample_theta(2, np.random.default_rng(23))
    cfg = QuadratureConfig(d=2, rel_tol=1e-8, capacity=1 << 11)
    res = integrate(cfg, bind(fam, theta).fn)
    exact = fam.exact(2, theta)
    assert res.status == "converged"
    assert abs(res.integral - exact) <= max(abs(exact) * 1e-6, 1e-12)
