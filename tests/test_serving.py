"""Serving path: prefill + decode must reproduce the full forward pass.

This is the strongest end-to-end check of the KV/SSM-state caches: for every
family with a decode step, running prefill on S tokens then decoding token
S+1..S+T must give the same logits as one full forward over the whole
sequence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import cache_init, model_decode, model_forward, model_init, model_prefill

DECODE_ARCHS = [
    "deepseek-7b",  # dense GQA
    "qwen3-32b",  # qk_norm
    "mamba2-370m",  # pure SSM state
    "jamba-v0.1-52b",  # hybrid + MoE
    "deepseek-v2-236b",  # MLA latent cache
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    b, s_prompt, n_dec = 2, 32, 4
    total = s_prompt + n_dec
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)), jnp.int32)

    # reference: full forward over the whole sequence
    ref_logits, _ = jax.jit(lambda p, t: model_forward(cfg, p, tokens=t))(
        params, tokens
    )

    # serve: prefill the prompt, then decode the remaining tokens one by one
    caches = cache_init(cfg, b, total)
    logits_p, caches = jax.jit(
        lambda p, t, c: model_prefill(cfg, p, t, c)
    )(params, tokens[:, :s_prompt], caches)
    np.testing.assert_allclose(
        np.asarray(logits_p),
        np.asarray(ref_logits[:, s_prompt - 1]),
        rtol=2e-4,
        atol=2e-4,
        err_msg=f"{arch}: prefill last-position logits",
    )

    decode = jax.jit(lambda p, t, c, pos: model_decode(cfg, p, t, c, pos))
    for i in range(n_dec):
        pos = s_prompt + i
        logits_d, caches = decode(params, tokens[:, pos], caches, jnp.asarray(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d),
            np.asarray(ref_logits[:, pos]),
            rtol=2e-4,
            atol=2e-4,
            err_msg=f"{arch}: decode step {i}",
        )


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-370m", "jamba-v0.1-52b", "deepseek-v2-236b"])
def test_chunked_prefill_matches_flat(arch):
    """Sarathi-style chunked prefill must equal the flat prefill pass."""
    from repro.models.model import model_prefill_chunked

    cfg = get_smoke_config(arch)
    params = model_init(cfg, jax.random.PRNGKey(0))
    b, s, chunk = 2, 64, 16
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    caches_a = cache_init(cfg, b, s + 4)
    flat, _ = jax.jit(lambda p, t, c: model_prefill(cfg, p, t, c))(
        params, tokens, caches_a
    )
    caches_b = cache_init(cfg, b, s + 4)
    chunked, caches_b = jax.jit(
        lambda p, t, c: model_prefill_chunked(cfg, p, t, c, chunk)
    )(params, tokens, caches_b)
    np.testing.assert_allclose(
        np.asarray(chunked), np.asarray(flat), rtol=3e-4, atol=3e-4,
        err_msg=f"{arch}: chunked vs flat prefill",
    )
    # decode continues correctly from the chunked caches
    logits_d, _ = jax.jit(lambda p, t, c, pos: model_decode(cfg, p, t, c, pos))(
        params, tokens[:, -1], caches_b, jnp.asarray(s)
    )
    assert bool(jnp.all(jnp.isfinite(logits_d)))


def test_generate_runs():
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config("deepseek-7b")
    params = model_init(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.arange(12, dtype=np.int32)[None].repeat(2, 0))
    out = generate(cfg, params, prompt, n_tokens=6, scfg=ServeConfig())
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_greedy_generation_is_deterministic():
    from repro.serving.engine import ServeConfig, generate

    cfg = get_smoke_config("mamba2-370m")
    params = model_init(cfg, jax.random.PRNGKey(1))
    prompt = jnp.asarray(np.arange(8, dtype=np.int32)[None])
    a = generate(cfg, params, prompt, n_tokens=5)
    b = generate(cfg, params, prompt, n_tokens=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
