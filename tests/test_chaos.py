"""Fault-injection chaos run + strict-mode CLI (subprocess-level).

Runs ``repro.service.chaos_selftest`` in a subprocess so that
``--xla_force_host_platform_device_count`` can take effect (the main pytest
process has already initialised jax with a single device).  The selftest
itself asserts survival, healthy-slot bit-parity, re-route provenance, and
crash/resume union parity under every injector in ``repro.service.faults``;
these tests re-check the reported summary and pin the scenario coverage.
Kept to a 2-device mesh to bound tier-1 wall time — CI additionally runs the
selftest at 4 virtual devices.
"""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(module, *args, env_extra=None, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-m", module, *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=_REPO,
        env=env,
    )


@pytest.fixture(scope="module")
def chaos_output():
    proc = _run("repro.service.chaos_selftest", "2")
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    assert line, proc.stdout[-4000:]
    return json.loads(line[-1][len("RESULT_JSON:") :])


def test_chaos_covers_every_injector_at_each_count(chaos_output):
    assert chaos_output["device_counts"] == [1, 2]
    base = {
        "baseline",
        "nan_injection",
        "slot_corruption",
        "crash_resume",
        "queue_storm",
        "deadline",
    }
    # device-loss scenarios need a surviving sub-mesh, so meshes >= 2 only
    elastic = {
        "device_kill_readmit",
        "device_kill_snapshot",
        "device_transient",
        "device_regrow",
    }
    for count, scen in chaos_output["scenarios"].items():
        expected = base if count == "devices_1" else base | elastic
        assert set(scen) == expected, (count, sorted(scen))


def test_chaos_healthy_slots_keep_bit_parity(chaos_output):
    for scen in chaos_output["scenarios"].values():
        assert scen["nan_injection"]["healthy_parity"]
        assert scen["slot_corruption"]["healthy_parity"]
        assert scen["deadline"]["healthy_parity"]


def test_chaos_reroutes_and_resume(chaos_output):
    for scen in chaos_output["scenarios"].values():
        assert scen["nan_injection"]["reroutes"] == 3
        assert scen["nan_injection"]["quarantines"] >= 6
        assert scen["crash_resume"]["union_parity"]
        assert scen["crash_resume"]["replayed"] > 0
        assert scen["queue_storm"]["n_results"] == 40


def test_chaos_device_loss_scenarios(chaos_output):
    scen = chaos_output["scenarios"]["devices_2"]
    assert scen["device_kill_readmit"]["evacuated"] > 0
    assert scen["device_kill_readmit"]["shrunk_to"] == 1
    assert scen["device_kill_readmit"]["healthy_parity"]
    assert scen["device_kill_snapshot"]["snapshot_recovered"] > 0
    assert scen["device_kill_snapshot"]["healthy_parity"]
    assert scen["device_transient"]["full_parity"]
    assert scen["device_transient"]["retries"] == 2
    assert scen["device_regrow"]["regrows"] >= 1
    assert scen["device_regrow"]["final_devices"] == 2


def test_chaos_elastic_restore_across_mesh_sizes(chaos_output):
    er = chaos_output["elastic_restore"]
    assert er["from_devices"] == 2
    assert er["union_parity"]
    assert er["restored_to"]["1"] > 0


# --- launch/integrate --strict ------------------------------------------------


def test_strict_passes_on_converged_run():
    proc = _run(
        "repro.launch.integrate",
        "--strict",
        "--integrand",
        "genz_gaussian",
        "--d",
        "2",
        "--rel-tol",
        "1e-4",
        "--capacity",
        str(1 << 10),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "STRICT" not in proc.stderr


def test_strict_fails_on_unconverged_run():
    proc = _run(
        "repro.launch.integrate",
        "--strict",
        "--integrand",
        "genz_gaussian",
        "--d",
        "2",
        "--rel-tol",
        "1e-10",
        "--max-iters",
        "2",
        "--capacity",
        str(1 << 10),
    )
    assert proc.returncode == 1, (proc.returncode, proc.stderr[-2000:])
    assert "STRICT" in proc.stderr
    assert "max_iters" in proc.stderr  # names the status and a fix hint
    # the normal result line still prints: strict fails loudly, not silently
    assert "[max_iters]" in proc.stdout


def test_strict_without_flag_exits_zero_on_unconverged():
    proc = _run(
        "repro.launch.integrate",
        "--integrand",
        "genz_gaussian",
        "--d",
        "2",
        "--rel-tol",
        "1e-10",
        "--max-iters",
        "2",
        "--capacity",
        str(1 << 10),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]


# --- launch/serve_quad --strict + --chaos-fail-device -------------------------


_SERVE_ARGS = (
    "--d", "2",
    "--n-requests", "8",
    "--batch-slots", "8",
    "--rel-tol", "1e-3",
    "--capacity", str(1 << 10),
    "--max-iters", "80",
)


def test_serve_strict_degraded_run_exits_zero_with_provenance():
    """A run that finishes only via device-loss evacuation passes strict
    mode, but each recovered request is called out with its provenance."""
    proc = _run(
        "repro.launch.serve_quad",
        *_SERVE_ARGS,
        "--devices", "2",
        "--chaos-fail-device", "1:2",
        "--strict",
        env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"},
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    # warnings and errors both ride the logging stream (stdout — serve_quad
    # is print-free by contract); the exit code is the machine interface
    assert "STRICT-DEGRADED" in proc.stdout, proc.stdout[-4000:]
    assert "retried_from=device_lost" in proc.stdout
    assert "evacuated=readmit" in proc.stdout
    assert "STRICT:" not in proc.stdout  # degraded, not failed


def test_serve_strict_fails_on_unconverged_run():
    proc = _run(
        "repro.launch.serve_quad",
        "--d", "2",
        "--n-requests", "2",
        "--batch-slots", "2",
        "--rel-tol", "1e-12",
        "--capacity", str(1 << 9),
        "--max-iters", "2",
        "--strict",
    )
    assert proc.returncode == 1, (proc.returncode, proc.stdout[-2000:])
    assert "STRICT:" in proc.stdout
    assert "max_iters" in proc.stdout  # names the status and a fix hint


def test_serve_chaos_flag_validation():
    proc = _run(
        "repro.launch.serve_quad",
        *_SERVE_ARGS,
        "--chaos-fail-device", "0:2",  # single-device fleet: nowhere to go
    )
    assert proc.returncode != 0
    assert "--devices >= 2" in proc.stderr
    proc = _run(
        "repro.launch.serve_quad",
        *_SERVE_ARGS,
        "--devices", "2",
        "--chaos-fail-device", "nonsense",
        env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"},
    )
    assert proc.returncode != 0
    assert "DEV:TICK" in proc.stderr
