"""Batched serving engine: prefill + greedy/temperature decode loop."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import cache_init, model_decode, model_prefill


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


def make_serve_fns(cfg: ModelConfig, scfg: ServeConfig):
    """Returns (prefill_fn, decode_fn), both jittable."""

    def prefill(params, tokens, caches, embeds=None):
        return model_prefill(cfg, params, tokens, caches, embeds=embeds)

    def decode(params, token, caches, pos, key):
        logits, caches = model_decode(cfg, params, token, caches, pos)
        if scfg.temperature > 0.0:
            nxt = jax.random.categorical(key, logits / scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), caches

    return jax.jit(prefill), jax.jit(decode)


def generate(
    cfg: ModelConfig,
    params,
    prompt: jnp.ndarray,  # (B, S_prompt) int32
    n_tokens: int,
    scfg: Optional[ServeConfig] = None,
    embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Greedy/temperature generation; returns (B, n_tokens) int32."""
    scfg = scfg or ServeConfig()
    b, s_prompt = prompt.shape
    s_front = embeds.shape[1] if embeds is not None else 0
    max_len = s_front + s_prompt + n_tokens
    caches = cache_init(cfg, b, max_len)
    prefill, decode = make_serve_fns(cfg, scfg)

    logits, caches = prefill(params, prompt, caches, embeds=embeds)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.PRNGKey(scfg.seed)

    out = [token]
    pos = s_front + s_prompt
    for i in range(n_tokens - 1):
        key, sub = jax.random.split(key)
        token, caches = decode(params, token, caches, jnp.asarray(pos), sub)
        out.append(token)
        pos += 1
    return jnp.stack(out, axis=1)
