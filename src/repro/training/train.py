"""Loss + train_step for every architecture family (shared code path)."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import model_forward
from repro.training.optimizer import OptimizerConfig, apply_updates


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: str = "none"  # none | full | dots
    microbatches: int = 1  # gradient accumulation steps
    z_loss_coef: float = 1e-3
    # Cast >=2-D fp32 params to this dtype BEFORE they are consumed: under
    # FSDP sharding the cast happens on the local shard, so the per-layer
    # weight all-gather moves bf16 instead of fp32 — half the collective
    # bytes and half the transient gathered-weight memory.  The fp32 master
    # copy stays sharded; gradients exit the cast boundary in fp32.
    param_gather_dtype: str = "bfloat16"
    opt: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)


def lm_loss(cfg: ModelConfig, params, batch, remat: str = "none"):
    """Cross-entropy next-token (or per-frame) loss.

    batch keys: "tokens" (B, St) and/or "embeds" (B, Sf, d); "labels"
    (B, S_out) aligned with the model's output positions; optional
    "loss_mask" (B, S_out).
    """
    logits, aux = model_forward(
        cfg,
        params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        remat=remat,
    )
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    metrics = {"ce_loss": loss}
    total = loss
    if "aux_loss" in aux:
        total = total + cfg.router_aux_coef * aux["aux_loss"]
        metrics["router_aux"] = aux["aux_loss"]
        metrics["dropped_frac"] = aux.get("dropped_frac", 0.0)
        z = aux.get("z_loss", 0.0)
        total = total + 1e-3 * z
    metrics["total_loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, param_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation: the batch's leading axis is split into
    ``tcfg.microbatches`` chunks folded through a lax.scan — peak activation
    memory scales with the microbatch, collectives with the full step.

    ``param_shardings`` (optional pytree of NamedShardings): accumulated
    gradients are constrained to the parameter layout BEFORE the optimizer —
    without the constraint GSPMD lowers the data-parallel gradient reduction
    as a full-tensor all-reduce (114 GiB/chip/step on qwen3-moe train);
    with it, a reduce-scatter feeding the sharded update (§Perf hillclimb A2).
    """

    gather_dtype = jnp.dtype(tcfg.param_gather_dtype)

    def cast_for_compute(params):
        if gather_dtype == jnp.float32:
            return params
        return jax.tree.map(
            lambda x: x.astype(gather_dtype)
            if (x.ndim >= 2 and x.dtype == jnp.float32)
            else x,
            params,
        )

    def grads_of(params, batch):
        # Differentiate wrt the ALREADY-CAST (bf16) tree: the cast is linear,
        # so accumulating the bf16-cotangent grads in fp32 outside equals
        # differentiating through the cast — but the cast (and the FSDP
        # all-gather it feeds) is now loop-invariant wrt the microbatch scan
        # and XLA hoists the gather to once per STEP instead of once per
        # microbatch (§Perf hillclimb A).
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, tcfg.remat), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        params_c = cast_for_compute(params)
        if tcfg.microbatches == 1:
            _, metrics, grads = grads_of(params_c, batch)
        else:
            m = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                assert b % m == 0, (b, m)
                return x.reshape(m, b // m, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(jnp.zeros_like, params)

            def body(acc, mb):
                _, metrics, grads = grads_of(params_c, mb)
                return jax.tree.map(jnp.add, acc, grads), metrics

            grads, metrics = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics = jax.tree.map(lambda x: x[-1], metrics)

        if param_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads,
                param_shardings,
            )
        params, opt_state, opt_metrics = apply_updates(
            tcfg.opt, params, grads, opt_state
        )
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
