"""AdamW + warmup-cosine schedule + global-norm clipping (self-contained).

Distributed-optimization extras:
- **ZeRO-1**: optimizer moments constrained to shard over the DP axes
  (logical axis "zero1") on the first divisible dimension — GSPMD then emits
  reduce-scatter/all-gather pairs around the update instead of a full
  all-reduce + replicated update.
- **bf16 gradient compression with error feedback**: gradients are rounded
  to bf16 before the update and the quantisation residual is carried to the
  next step, emulating a compressed DP all-reduce while keeping convergence
  (the residual never leaves the device that produced it).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_ctx


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = False
    grad_compression: str = "none"  # none | bf16_ef


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac)
    )
    return cfg.lr * warm * cos


def _zero1_constrain(leaf):
    """Shard the first divisible dim of an optimizer moment over DP axes."""
    ctx = current_ctx()
    if ctx is None or leaf.ndim == 0:
        return leaf
    size = ctx.axis_size(ctx.rules.get("zero1"))
    if size <= 1:
        return leaf
    for i, dim in enumerate(leaf.shape):
        if dim % size == 0 and dim >= size:
            names = [None] * leaf.ndim
            names[i] = "zero1"
            from repro.distributed.sharding import shard

            return shard(leaf, *names)
    return leaf


def init_opt_state(cfg: OptimizerConfig, params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    state = {
        "mu": zeros,
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compression == "bf16_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    if cfg.zero1:
        state["mu"] = jax.tree.map(_zero1_constrain, state["mu"])
        state["nu"] = jax.tree.map(_zero1_constrain, state["nu"])
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)

    if cfg.grad_compression == "bf16_ef":
        # add residual, round to bf16, keep the new residual
        with_ef = jax.tree.map(lambda g, e: g + e, grads, state["ef"])
        compressed = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), with_ef
        )
        new_ef = jax.tree.map(lambda g, c: g - c, with_ef, compressed)
        grads = compressed
    else:
        new_ef = None

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1 - cfg.b1**count.astype(jnp.float32)
    b2c = 1 - cfg.b2**count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        if cfg.zero1:
            m = _zero1_constrain(m)
            v = _zero1_constrain(v)
        return p - lr * step, m, v

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
