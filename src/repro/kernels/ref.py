"""Pure-jnp oracle for the fused GM evaluation kernel.

Delegates to :func:`repro.core.genz_malik.gm_eval_reference` — a single
source of truth for weights/generators shared by kernel and oracle — but
exposes the kernel's SoA ``(d, N)`` calling convention so tests compare
byte-identical interfaces.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.genz_malik import gm_eval_reference


def genz_malik_eval_soa_ref(
    f: Callable[[jnp.ndarray], jnp.ndarray],
    centers: jnp.ndarray,  # (d, N)
    halfw: jnp.ndarray,  # (d, N)
):
    """Reference with the same signature/layout as the Pallas kernel."""
    i7, i5, i3, diffs = gm_eval_reference(f, centers.T, halfw.T)
    return i7, i5, i3, diffs.T  # diffs back to (d, N)
