"""Jit'd public wrappers around the Pallas kernels.

`genz_malik_eval` is the entry point used by
:class:`repro.core.rules.GenzMalikRule` when ``use_kernel=True``.  It adapts
the region store's AoS ``(B, d)`` layout to the kernel's SoA ``(d, B)``
layout, pads the batch to the block size, and dispatches to the fused
Pallas kernel (``interpret=True`` executes the kernel body on CPU — the
validation mode for this container; on TPU pass ``interpret=False``).

ParamIntegrand families ride the same kernel with their coefficients as a
proper operand: ``theta`` (a pytree of per-axis coefficient leaves) is
flattened into an ``(n_theta, B)``-broadcast row matrix and rebuilt into the
pytree inside the kernel wrapper, so the integrand never closes over a
theta array (``pallas_call`` rejects captured constants, and the batch
service passes theta as a traced, vmapped value).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.genz_malik_eval import genz_malik_eval_soa

# Default chosen by the VMEM budget sweep in EXPERIMENTS.md §Perf: the
# working set per block is ~(4 + 4d) * BLOCK * 4 bytes; 512 lanes keeps the
# d=13 worst case ~110 KiB, far under the ~16 MiB v5e VMEM, while giving the
# MXU-free VPU pipeline full 128-lane occupancy x 4 sublane tiles.
# This is the single source of truth for the block size: GenzMalikRule and
# QuadratureConfig use 0 to mean "defer to this default".
DEFAULT_BLOCK_REGIONS = 512


def block_and_pad(b: int, block_regions: int = 0) -> tuple[int, int]:
    """Resolve (block, pad) for a batch of ``b`` regions.

    The single place that rounds an evaluation batch (in particular the
    active-window sizes chosen by the adaptive drivers) up to a block
    multiple: batches smaller than the block shrink the block to the batch,
    larger batches are padded to the next multiple.  ``block_regions=0``
    selects :data:`DEFAULT_BLOCK_REGIONS`.
    """
    block_regions = block_regions or DEFAULT_BLOCK_REGIONS
    block = min(block_regions, b) if b % block_regions else block_regions
    return block, (-b) % block


@lru_cache(maxsize=None)
def _theta_wrapper(f: Callable, treedef, sizes: tuple[int, ...]) -> Callable:
    """Kernel-side adapter ``f(x, theta_rows) -> f(x, theta_pytree)``.

    Splits the stacked ``(n_theta, BLOCK)`` operand tile back into the
    family's theta leaves (each a broadcast ``(leaf_len, BLOCK)`` slab the
    integrand consumes via ``integrands._col``).  Cached so repeated calls
    hand ``genz_malik_eval_soa`` the *same* function object — its jit cache
    keys on ``f`` statically, and a fresh closure per call would recompile
    the kernel every iteration.
    """
    splits = tuple(int(s) for s in np.cumsum(sizes)[:-1])

    def f_soa(x: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
        parts = jnp.split(rows, splits, axis=0) if splits else [rows]
        return f(x, jax.tree.unflatten(treedef, parts))

    return f_soa


def genz_malik_eval(
    f: Callable,
    centers: jnp.ndarray,  # (B, d) AoS, as stored by RegionState
    halfw: jnp.ndarray,  # (B, d)
    *,
    theta=None,  # optional ParamIntegrand theta pytree, leaves (leaf_len,)
    block_regions: int = 0,
    interpret: bool = True,
):
    """Fused GM rule evaluation. Returns (i7, i5, i3, diffs[B, d]).

    Without ``theta``, ``f`` maps ``(d, N)`` coordinates to ``(N,)`` values.
    With ``theta``, ``f`` is a family function ``f(x, theta)`` and the theta
    leaves enter the kernel as broadcast operand rows (see module docstring).
    """
    b, d = centers.shape
    block, pad = block_and_pad(b, block_regions)
    ct = centers.T
    ht = halfw.T
    if pad:
        ct = jnp.pad(ct, ((0, 0), (0, pad)))
        # halfwidth 1 on padded lanes avoids spurious inf/nan in integrands
        ht = jnp.pad(ht, ((0, 0), (0, pad)), constant_values=1.0)
    if theta is None:
        i7, i5, i3, diffs = genz_malik_eval_soa(
            f, ct, ht, block_regions=block, interpret=interpret
        )
    else:
        leaves, treedef = jax.tree.flatten(theta)
        leaves = [jnp.asarray(leaf, centers.dtype).reshape(-1) for leaf in leaves]
        sizes = tuple(int(leaf.shape[0]) for leaf in leaves)
        rows = jnp.concatenate(leaves)
        theta_rows = jnp.broadcast_to(rows[:, None], (rows.shape[0], ct.shape[1]))
        i7, i5, i3, diffs = genz_malik_eval_soa(
            _theta_wrapper(f, treedef, sizes),
            ct,
            ht,
            theta_rows,
            block_regions=block,
            interpret=interpret,
        )
    return i7[:b], i5[:b], i3[:b], diffs[:, :b].T
