"""Jit'd public wrappers around the Pallas kernels.

`genz_malik_eval` is the entry point used by
:class:`repro.core.rules.GenzMalikRule` when ``use_kernel=True``.  It adapts
the region store's AoS ``(B, d)`` layout to the kernel's SoA ``(d, B)``
layout, pads the batch to the block size, and dispatches to the fused
Pallas kernel (``interpret=True`` executes the kernel body on CPU — the
validation mode for this container; on TPU pass ``interpret=False``).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.kernels.genz_malik_eval import genz_malik_eval_soa

# Default chosen by the VMEM budget sweep in EXPERIMENTS.md §Perf: the
# working set per block is ~(4 + 4d) * BLOCK * 4 bytes; 512 lanes keeps the
# d=13 worst case ~110 KiB, far under the ~16 MiB v5e VMEM, while giving the
# MXU-free VPU pipeline full 128-lane occupancy x 4 sublane tiles.
# This is the single source of truth for the block size: GenzMalikRule and
# QuadratureConfig use 0 to mean "defer to this default".
DEFAULT_BLOCK_REGIONS = 512


def block_and_pad(b: int, block_regions: int = 0) -> tuple[int, int]:
    """Resolve (block, pad) for a batch of ``b`` regions.

    The single place that rounds an evaluation batch (in particular the
    active-window sizes chosen by the adaptive drivers) up to a block
    multiple: batches smaller than the block shrink the block to the batch,
    larger batches are padded to the next multiple.  ``block_regions=0``
    selects :data:`DEFAULT_BLOCK_REGIONS`.
    """
    block_regions = block_regions or DEFAULT_BLOCK_REGIONS
    block = min(block_regions, b) if b % block_regions else block_regions
    return block, (-b) % block


def genz_malik_eval(
    f: Callable[[jnp.ndarray], jnp.ndarray],
    centers: jnp.ndarray,  # (B, d) AoS, as stored by RegionState
    halfw: jnp.ndarray,  # (B, d)
    *,
    block_regions: int = 0,
    interpret: bool = True,
):
    """Fused GM rule evaluation. Returns (i7, i5, i3, diffs[B, d])."""
    b, d = centers.shape
    block, pad = block_and_pad(b, block_regions)
    ct = centers.T
    ht = halfw.T
    if pad:
        ct = jnp.pad(ct, ((0, 0), (0, pad)))
        # halfwidth 1 on padded lanes avoids spurious inf/nan in integrands
        ht = jnp.pad(ht, ((0, 0), (0, pad)), constant_values=1.0)
    i7, i5, i3, diffs = genz_malik_eval_soa(
        f, ct, ht, block_regions=block, interpret=interpret
    )
    return i7[:b], i5[:b], i3[:b], diffs[:, :b].T
