"""Fused Genz-Malik evaluation kernel (Pallas TPU).

The paper's hot spot is the per-iteration evaluation of the GM rule over the
whole active region population.  On GPU the reference code (PAGANI-style)
streams SoA region arrays through a CUDA kernel with coalesced loads.  The
TPU-native rethink (DESIGN.md §2):

- regions ride the 128-wide *lane* axis, the d coordinate axes ride the
  sublane axis — one `(d, BLOCK)` VMEM tile per block of regions;
- the rule's node coordinates are *generated on the fly* inside the kernel
  (centre + lambda * halfwidth * sign pattern) and the integrand is inlined,
  so no `(n_nodes, d)` coordinate matrix and no `(B, n_nodes)` value matrix
  ever exist in HBM — the kernel reads ``2 * d * BLOCK`` floats and writes
  ``(3 + d) * BLOCK`` floats, i.e. arithmetic intensity grows with
  ``n_nodes(d) = O(2^d)``, putting the kernel firmly in the compute-bound
  regime of the v5e roofline (see benchmarks/roofline.py);
- the O(2^d) full-sign group is a `fori_loop` with the sign pattern decoded
  from the loop counter's bits (no table in memory);
- the degree-7, degree-5, degree-3 sums and the per-axis fourth differences
  (axis-selection heuristic) are accumulated in registers/VMEM in the same
  pass — the embedded family costs zero extra evaluations by construction.

Weights/lambdas come from `repro.core.genz_malik` so kernel and oracle can
never drift apart.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.genz_malik import (
    FOURTH_DIFF_RATIO,
    LAMBDA2,
    LAMBDA3,
    LAMBDA4,
    LAMBDA5,
    gm_weights,
)


def _kernel(
    centers_ref,  # (d, B) VMEM
    halfw_ref,  # (d, B) VMEM
    *refs,  # [theta_ref (n_theta, B)] + i7 (1, B), i5, i3, diffs (d, B)
    f: Callable[..., jnp.ndarray],
    d: int,
    has_theta: bool,
):
    if has_theta:
        # ParamIntegrand families take their per-problem coefficients as a
        # proper kernel operand: an (n_theta, B) ref whose rows are the
        # flattened theta leaves broadcast over the lane axis (a closure
        # over theta would be a captured constant, which pallas_call
        # rejects — and under the batch service's vmap, a traced value).
        theta_ref, i7_ref, i5_ref, i3_ref, diffs_ref = refs
        theta = theta_ref[...]
    else:
        i7_ref, i5_ref, i3_ref, diffs_ref = refs
        theta = None
    c = centers_ref[...]
    h = halfw_ref[...]
    dtype = c.dtype
    w = gm_weights(d)

    def feval(x):
        v = f(x) if theta is None else f(x, theta)
        return v.reshape(1, -1)  # keep 2-D for TPU layout

    f0 = feval(c)
    sum2 = jnp.zeros_like(f0)
    sum3 = jnp.zeros_like(f0)
    diffs = []
    rows = jax.lax.broadcasted_iota(jnp.int32, (d, 1), 0)

    # --- single-coordinate groups (lambda2, lambda3) + fourth differences ----
    for i in range(d):
        onehot = (rows == i).astype(dtype)
        d2 = LAMBDA2 * h * onehot
        d3 = LAMBDA3 * h * onehot
        f2p = feval(c + d2)
        f2m = feval(c - d2)
        f3p = feval(c + d3)
        f3m = feval(c - d3)
        sum2 = sum2 + f2p + f2m
        sum3 = sum3 + f3p + f3m
        diffs.append(
            jnp.abs(f2p + f2m - 2.0 * f0 - FOURTH_DIFF_RATIO * (f3p + f3m - 2.0 * f0))
        )

    # --- pair group (lambda4, lambda4) ----------------------------------------
    sum4 = jnp.zeros_like(f0)
    for i in range(d):
        for j in range(i + 1, d):
            ei = (rows == i).astype(dtype)
            ej = (rows == j).astype(dtype)
            di = LAMBDA4 * h * ei
            dj = LAMBDA4 * h * ej
            sum4 = (
                sum4
                + feval(c + di + dj)
                + feval(c + di - dj)
                + feval(c - di + dj)
                + feval(c - di - dj)
            )

    # --- full-sign corner group (lambda5): signs decoded from loop bits ------
    def corner_body(k, acc):
        bits = jnp.stack([(k >> i) & 1 for i in range(d)]).astype(dtype)
        signs = (1.0 - 2.0 * bits).reshape(d, 1)
        return acc + feval(c + LAMBDA5 * h * signs)

    sum5 = jax.lax.fori_loop(0, 2**d, corner_body, jnp.zeros_like(f0))

    scale = jnp.prod(h, axis=0, keepdims=True)  # (1, B)
    i7_ref[...] = scale * (
        w.w1 * f0 + w.w2 * sum2 + w.w3 * sum3 + w.w4 * sum4 + w.w5 * sum5
    )
    i5_ref[...] = scale * (w.e1 * f0 + w.e2 * sum2 + w.e3 * sum3 + w.e4 * sum4)
    i3_ref[...] = scale * (w.t1 * f0 + w.t3 * sum3)
    diffs_ref[...] = jnp.concatenate(diffs, axis=0)


@functools.partial(
    jax.jit, static_argnames=("f", "block_regions", "interpret")
)
def genz_malik_eval_soa(
    f: Callable,
    centers: jnp.ndarray,  # (d, C) SoA
    halfw: jnp.ndarray,  # (d, C)
    theta_rows: jnp.ndarray | None = None,  # (n_theta, C) broadcast operand
    *,
    block_regions: int,
    interpret: bool = True,
):
    """Run the fused GM kernel over an SoA batch. Returns (i7, i5, i3, diffs).

    ``block_regions`` is required (the batch must already be padded to a
    block multiple): block sizing and padding live in ``kernels.ops``, the
    single source of truth for the default.

    ``theta_rows`` carries a ParamIntegrand family's flattened coefficients
    as an extra ``(n_theta, C)`` input (each row one scalar broadcast over
    the lane axis); ``f`` then has signature ``f(x, theta_block)`` with
    ``theta_block`` the matching ``(n_theta, BLOCK)`` VMEM tile.  Packing
    and unpacking of the theta pytree live in ``kernels.ops``.
    """
    d, n = centers.shape
    if n % block_regions:
        raise ValueError(f"region count {n} not divisible by block {block_regions}")
    grid = (n // block_regions,)
    dtype = centers.dtype

    kernel = functools.partial(_kernel, f=f, d=d, has_theta=theta_rows is not None)
    row_spec = pl.BlockSpec((d, block_regions), lambda i: (0, i))
    one_spec = pl.BlockSpec((1, block_regions), lambda i: (0, i))

    in_specs = [row_spec, row_spec]
    operands = [centers, halfw]
    if theta_rows is not None:
        in_specs.append(
            pl.BlockSpec((theta_rows.shape[0], block_regions), lambda i: (0, i))
        )
        operands.append(theta_rows)

    i7, i5, i3, diffs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[one_spec, one_spec, one_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), dtype),
            jax.ShapeDtypeStruct((1, n), dtype),
            jax.ShapeDtypeStruct((1, n), dtype),
            jax.ShapeDtypeStruct((d, n), dtype),
        ],
        interpret=interpret,
    )(*operands)
    return i7[0], i5[0], i3[0], diffs
