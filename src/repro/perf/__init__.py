"""Performance observatory: measured rooflines, kernel cost capture,
regression-gated benchmark tracking (DESIGN.md §9).

The subsystem has four layers, each usable standalone:

- :mod:`~repro.perf.machine` — micro-benchmark the *current* device into a
  machine file (peak FLOP/s via a timed matmul, memory bandwidth via timed
  saxpy/reduction probes) plus documented hardware presets (the old
  ``benchmarks/roofline.py`` v5e constants live on as the ``"v5e"`` preset);
- :mod:`~repro.perf.catalog` — lower the *real* compiled programs (GM rule
  eval at each window rung, the windowed advance, the VEGAS iterate, the
  fused sharded-service dispatch), record XLA ``cost_analysis()`` FLOPs and
  bytes alongside measured wall time, and derive predicted-vs-measured
  roofline fractions per (kernel, rung, d);
- :mod:`~repro.perf.regress` — compare two normalized ``BENCH_summary.json``
  files with noise-tolerant thresholds (CI perf gate);
- :mod:`~repro.perf.report` — render machine file + catalog + bench history
  + telemetry latency/idle views into one markdown/HTML report under
  ``results/perf/``.

Everything here is measurement-side only: nothing in this package is on any
serving or integration hot path, and nothing records inside traced code.
"""

from repro.perf.machine import (
    PRESETS,
    load_machine,
    profile_machine,
    resolve_machine,
    save_machine,
)

__all__ = [
    "PRESETS",
    "load_machine",
    "profile_machine",
    "resolve_machine",
    "save_machine",
]
