"""Bench regression gate: compare two ``BENCH_summary.json`` files.

``python -m repro.perf.regress baseline.json candidate.json`` compares every
tracked metric (lower is better — the summary normalizes each benchmark row
to its wall-time column) with noise-tolerant thresholds:

- ratio > ``--fail-ratio`` (default 1.3x) — **FAIL**, exit 1;
- ratio > ``--warn-ratio`` (default 1.1x) — warn, exit 0;
- ratio < 1 / warn-ratio — reported as an improvement.

Both files must carry the provenance meta header ``benchmarks/_common.py``
writes.  Baseline/candidate pairs from different *platforms* are rejected
outright (exit 2): a cpu-vs-tpu wall-time ratio is not a regression signal.
Differing device kinds on the same platform (e.g. two CPU models) only warn
— that is exactly the cross-machine noise the relaxed CI thresholds exist
for (see the perf-smoke job in ``.github/workflows/ci.yml``).

Exit codes: 0 ok/warn, 1 at least one metric regressed past the fail
threshold, 2 the files are unusable (schema or platform mismatch).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple

#: default noise-tolerant thresholds (same-machine comparisons)
FAIL_RATIO = 1.3
WARN_RATIO = 1.1


class RegressError(ValueError):
    """Baseline/candidate pair is unusable (schema or platform mismatch)."""


def load_summary(path: str) -> Dict[str, Any]:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "metrics" not in data or "meta" not in data:
        raise RegressError(
            f"{path} is not a BENCH_summary file: expected "
            '{"meta": {...}, "metrics": {...}} (write one with '
            "`python -m benchmarks.run`)"
        )
    return data


def check_compatible(
    baseline: Dict[str, Any], candidate: Dict[str, Any], allow_mismatch: bool = False
) -> List[str]:
    """Platform guard; returns warning lines, raises on a hard mismatch."""
    warnings: List[str] = []
    b_meta, c_meta = baseline.get("meta", {}), candidate.get("meta", {})
    b_plat, c_plat = b_meta.get("platform"), c_meta.get("platform")
    if b_plat != c_plat and not allow_mismatch:
        raise RegressError(
            f"platform mismatch: baseline ran on {b_plat!r}, candidate on "
            f"{c_plat!r} — wall-time ratios across platforms are not a "
            "regression signal. Re-record the baseline on this platform "
            "(`python -m benchmarks.run`) or pass --allow-platform-mismatch "
            "if you really want the comparison."
        )
    for key in ("device_kind", "device_count", "jax_version"):
        if b_meta.get(key) != c_meta.get(key):
            warnings.append(
                f"meta drift: {key} baseline={b_meta.get(key)!r} "
                f"candidate={c_meta.get(key)!r} — expect timing noise"
            )
    return warnings


def compare(
    baseline: Dict[str, Any],
    candidate: Dict[str, Any],
    fail_ratio: float = FAIL_RATIO,
    warn_ratio: float = WARN_RATIO,
) -> Tuple[List[Dict[str, Any]], List[str]]:
    """Per-metric comparison rows plus coverage warnings.

    Each row: ``{metric, baseline, candidate, ratio, verdict}`` with verdict
    one of ``fail`` / ``warn`` / ``ok`` / ``improved``.  Metrics present on
    only one side produce coverage warnings, never failures — a renamed or
    newly added benchmark must not block CI, it must be re-baselined.
    """
    b, c = baseline["metrics"], candidate["metrics"]
    rows: List[Dict[str, Any]] = []
    warnings: List[str] = []
    for name in sorted(set(b) | set(c)):
        if name not in c:
            warnings.append(f"metric dropped from candidate: {name}")
            continue
        if name not in b:
            warnings.append(f"new metric (no baseline): {name}")
            continue
        old, new = float(b[name]), float(c[name])
        if old <= 0:
            warnings.append(f"non-positive baseline for {name}: {old}")
            continue
        ratio = new / old
        if ratio > fail_ratio:
            verdict = "fail"
        elif ratio > warn_ratio:
            verdict = "warn"
        elif ratio < 1.0 / warn_ratio:
            verdict = "improved"
        else:
            verdict = "ok"
        rows.append(
            {
                "metric": name,
                "baseline": old,
                "candidate": new,
                "ratio": ratio,
                "verdict": verdict,
            }
        )
    return rows, warnings


def render_rows(rows: List[Dict[str, Any]]) -> str:
    out = [
        "| metric | baseline (us) | candidate (us) | ratio | verdict |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        mark = {"fail": "**FAIL**", "warn": "warn", "improved": "improved"}.get(
            r["verdict"], "ok"
        )
        out.append(
            f"| {r['metric']} | {r['baseline']:.1f} | {r['candidate']:.1f} | "
            f"{r['ratio']:.2f}x | {mark} |"
        )
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Compare two BENCH_summary.json files (perf gate)."
    )
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--fail-ratio", type=float, default=FAIL_RATIO)
    ap.add_argument("--warn-ratio", type=float, default=WARN_RATIO)
    ap.add_argument(
        "--allow-platform-mismatch",
        action="store_true",
        help="compare across platforms anyway (ratios are then advisory)",
    )
    args = ap.parse_args(argv)
    if args.fail_ratio < args.warn_ratio:
        ap.error("--fail-ratio must be >= --warn-ratio")

    try:
        baseline = load_summary(args.baseline)
        candidate = load_summary(args.candidate)
        warnings = check_compatible(
            baseline, candidate, allow_mismatch=args.allow_platform_mismatch
        )
    except RegressError as e:
        print(f"regress: {e}")
        return 2

    rows, coverage = compare(
        baseline, candidate, fail_ratio=args.fail_ratio, warn_ratio=args.warn_ratio
    )
    for w in warnings + coverage:
        print(f"warning: {w}")
    if rows:
        print(render_rows(rows))
    n_fail = sum(r["verdict"] == "fail" for r in rows)
    n_warn = sum(r["verdict"] == "warn" for r in rows)
    n_imp = sum(r["verdict"] == "improved" for r in rows)
    print(
        f"\n{len(rows)} metrics compared: {n_fail} failed "
        f"(> {args.fail_ratio:.2f}x), {n_warn} warned "
        f"(> {args.warn_ratio:.2f}x), {n_imp} improved"
    )
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
