"""Kernel cost catalog: lower the real compiled programs, predict, measure.

For each kernel the repo actually dispatches — the GM rule evaluation at
each eval-window rung, the windowed advance at each advance rung, the VEGAS
iterate, and the fused sharded-service dispatch — this module:

1. builds a representative input (a region store with the window full of
   live regions, a warmed VEGAS state, an admitted fleet),
2. lowers and compiles the *same jitted function the drivers run* and reads
   XLA's ``cost_analysis()`` FLOPs / bytes-accessed plus
   ``memory_analysis()`` buffer sizes,
3. times the compiled executable (best-of-``reps`` wall clock), and
4. predicts a roofline bound from a machine file
   (:mod:`repro.perf.machine`): ``predicted_s = max(flops / peak_flops,
   bytes / mem_bw)`` and reports ``roofline_frac = predicted_s /
   measured_s`` — the fraction of the machine's roofline the kernel
   actually achieves (1.0 = running at the bound).

**Scan-body caveat** (same issue ``benchmarks/roofline.py`` documents for
the LM stack): ``HloCostAnalysis`` counts a ``lax.scan``/``while`` body
ONCE regardless of trip count.  The fused service dispatch scans
``sync_every`` iterations per call, so its raw HLO counts are scaled by
``scan_trips = sync_every`` before predicting; every other cataloged
kernel is scan-free at the top level (``scan_trips = 1``).  The VEGAS
iterate's internal ``_ordered_sum`` scan runs over already-reduced shard
partials — negligible against the per-sample work, so no correction is
applied (recorded trip count 1).

Timing calls the AOT-compiled executable directly (the lowered object from
step 2), so the measured program is *exactly* the costed program — not a
re-traced sibling.  The drivers donate state buffers on non-CPU platforms;
the catalog therefore threads each call's output state back in as the next
call's input, which keeps repeated timing valid under donation.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.perf.machine import DEFAULT_PATH as MACHINE_PATH, resolve_machine

_REPO = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
)

#: default catalog location, next to the machine file it was predicted from
DEFAULT_PATH = os.path.join(_REPO, "results", "perf", "kernel_catalog.json")

#: kernel names the catalog can produce (report + tests key off these)
KERNELS = ("gm_eval", "advance", "vegas_iterate", "service_dispatch")


def _cost_of(compiled) -> Dict[str, float]:
    """FLOPs / bytes / buffer sizes of a compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax <= 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    cost = cost or {}
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    try:
        mem = compiled.memory_analysis()
        out["arg_bytes"] = float(mem.argument_size_in_bytes)
        out["out_bytes"] = float(mem.output_size_in_bytes)
        out["temp_bytes"] = float(mem.temp_size_in_bytes)
    except Exception:  # noqa: BLE001 — memory stats are best-effort
        pass
    return out


def _time_compiled(compiled, args: tuple, reps: int, state_index: Optional[int]) -> float:
    """Best-of-``reps`` wall time of one executable call.

    When ``state_index`` is given, output element ``state_index`` (or the
    whole output, for state->state kernels returning a single value) is fed
    back as the first argument of the next call — repeated timing stays
    valid when the platform donates the state buffers.
    """
    import jax

    def feed(out, cur_args):
        if state_index is None:
            return cur_args
        new_state = out if not isinstance(out, tuple) else out[state_index]
        return (new_state,) + cur_args[1:]

    out = compiled(*args)  # first dispatch (executable is already compiled)
    jax.block_until_ready(out)
    args = feed(out, args)
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        out = compiled(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
        args = feed(out, args)
    return best


def _entry(
    kernel: str,
    compiled,
    args: tuple,
    *,
    d: int,
    rung: Optional[int],
    reps: int,
    scan_trips: int = 1,
    state_index: Optional[int] = 0,
    **extra: Any,
) -> Dict[str, Any]:
    cost = _cost_of(compiled)
    measured = _time_compiled(compiled, args, reps, state_index)
    return {
        "kernel": kernel,
        "d": d,
        "rung": rung,
        "scan_trips": scan_trips,
        "measured_s": measured,
        **cost,
        **extra,
    }


def predict(entry: Dict[str, Any], machine: Dict[str, Any]) -> Dict[str, Any]:
    """Attach roofline predictions from ``machine`` to a measured entry.

    Returns a new dict; ``entry`` is not mutated.  ``flops_total`` /
    ``bytes_total`` are the HLO counts scaled by the scan trip count (see
    module docstring); ``roofline_frac`` is predicted/measured wall time.
    """
    trips = int(entry.get("scan_trips", 1))
    flops = entry["flops"] * trips
    byts = entry["bytes"] * trips
    compute_s = flops / machine["peak_flops"]
    memory_s = byts / machine["mem_bw"]
    predicted = max(compute_s, memory_s)
    measured = entry["measured_s"]
    return {
        **entry,
        "flops_total": flops,
        "bytes_total": byts,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "predicted_s": predicted,
        "dominant": "compute" if compute_s >= memory_s else "memory",
        "roofline_frac": (predicted / measured) if measured > 0 else 0.0,
        "achieved_gflops": flops / measured / 1e9 if measured > 0 else 0.0,
        "achieved_gbs": byts / measured / 1e9 if measured > 0 else 0.0,
    }


# --- representative inputs ----------------------------------------------------


def _populated_region_state(cfg, n_active: int, seed: int = 0):
    """A region store with ``n_active`` live+fresh synthetic regions.

    Same construction as ``benchmarks/eval_window.py``: random boxes well
    inside the unit domain, everything beyond ``n_active`` inactive — the
    compaction invariant's steady-state shape.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import region_store

    rng = np.random.default_rng(seed)
    C, d = cfg.capacity, cfg.d
    centers = np.zeros((C, d))
    halfw = np.zeros((C, d))
    centers[:n_active] = rng.uniform(0.2, 0.8, (n_active, d))
    halfw[:n_active] = rng.uniform(0.01, 0.1, (n_active, d))
    mask = np.arange(C) < n_active
    return dataclasses.replace(
        region_store.empty_state(C, d, jnp.dtype(cfg.dtype)),
        centers=jnp.asarray(centers),
        halfw=jnp.asarray(halfw),
        active=jnp.asarray(mask),
        fresh=jnp.asarray(mask),
    )


def gm_eval_entries(cfg, reps: int) -> List[Dict[str, Any]]:
    """GM rule evaluation at every eval-window rung, window full of work."""
    import jax

    from repro.core.adaptive import eval_ladder, make_eval_step
    from repro.core.rules import make_rule

    rule = make_rule(cfg)
    out = []
    for w in eval_ladder(cfg):
        state = _populated_region_state(cfg, n_active=w)
        step = jax.jit(make_eval_step(cfg, rule, window=w))
        compiled = step.lower(state).compile()
        out.append(
            _entry(
                "gm_eval",
                compiled,
                (state,),
                d=cfg.d,
                rung=w,
                reps=reps,
                regions=w,
                evals_per_region=rule.n_evals_per_region,
            )
        )
    return out


def advance_entries(cfg, reps: int) -> List[Dict[str, Any]]:
    """Windowed advance (classify + split + compact) at every advance rung.

    The representative population is ``rung // 2`` live regions — the
    largest count whose doubled advance target the rung still covers, i.e.
    the heaviest workload this rung is ever picked for.
    """
    import jax
    import numpy as np

    from repro.core.adaptive import advance_ladder, make_advance_step, make_eval_step
    from repro.core.rules import make_rule

    lo = np.asarray(cfg.lo(), np.float64)
    hi = np.asarray(cfg.hi(), np.float64)
    total_volume = float(np.prod(hi - lo))
    rule = make_rule(cfg)
    out = []
    for w in advance_ladder(cfg):
        n_active = max(w // 2, 1)
        state = _populated_region_state(cfg, n_active=n_active)
        # est/err/axis must hold real rule output for classify to threshold
        state = jax.jit(make_eval_step(cfg, rule, window=w))(state)
        adv = make_advance_step(cfg, total_volume, hi - lo, window=w)
        step = jax.jit(lambda s, _adv=adv: _adv(s))
        compiled = step.lower(state).compile()
        out.append(
            _entry(
                "advance",
                compiled,
                (state,),
                d=cfg.d,
                rung=w,
                reps=reps,
                regions=n_active,
            )
        )
    return out


def vegas_entries(cfg, reps: int) -> List[Dict[str, Any]]:
    """The full VEGAS iterate: sample -> map -> integrand -> reduce -> adapt."""
    import jax

    from repro.core.integrands import get as get_integrand
    from repro.mc import engine as mc_engine

    fn = get_integrand(cfg.integrand).fn
    iterate = jax.jit(mc_engine.make_iterate(cfg, fn))
    state = mc_engine.init_state(cfg)
    compiled = iterate.lower(state).compile()
    return [
        _entry(
            "vegas_iterate",
            compiled,
            (state,),
            d=cfg.d,
            rung=None,
            reps=reps,
            samples=cfg.mc_samples,
        )
    ]


def dispatch_entries(cfg, reps: int) -> List[Dict[str, Any]]:
    """The fused sharded-service dispatch (``BatchEngine.run``).

    A full fleet is admitted at tolerances no slot can reach within one
    fused window, so the timed dispatch executes all ``sync_every``
    iterations (no early exit) — and the HLO scan-body counts are scaled by
    exactly that trip count (``scan_trips``, see module docstring).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.service.batch_engine import BatchEngine

    engine = BatchEngine(cfg)
    state = engine.init()
    rng = np.random.default_rng(0)
    for slot in range(engine.n_slots):
        theta = engine.family.sample_theta(cfg.d, rng)
        state = engine.admit(state, slot, theta, 1e-14, 1e-30)
    args = (
        state,
        jnp.asarray(cfg.sync_every, jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    compiled = engine._run.lower(*args).compile()
    return [
        _entry(
            "service_dispatch",
            compiled,
            args,
            d=cfg.d,
            rung=None,
            reps=reps,
            scan_trips=cfg.sync_every,
            slots=engine.n_slots,
            devices=engine.n_devices,
        )
    ]


# --- catalog assembly ---------------------------------------------------------


def default_configs(fast: bool = True) -> Dict[str, Any]:
    """The (kernel kind -> config) grid the standard catalog sweeps.

    Reduced shapes in ``fast`` mode so the CI perf-smoke job finishes in
    minutes; ``fast=False`` uses the benchmark-scale shapes.
    """
    from repro.core.config import QuadratureConfig

    cub = QuadratureConfig(
        d=5,
        integrand="f4",
        capacity=(1 << 11) if fast else (1 << 13),
    ).validate()
    veg = QuadratureConfig(
        d=8,
        integrand="f4",
        backend="vegas",
        mc_samples=8192 if fast else 65536,
        mc_shards=8,
    ).validate()
    svc = QuadratureConfig(
        d=3,
        integrand="genz_gaussian",
        capacity=(1 << 9) if fast else (1 << 11),
        batch_slots=4 if fast else 16,
        sync_every=4,
    ).validate()
    return {"gm_eval": cub, "advance": cub, "vegas_iterate": veg, "service_dispatch": svc}


def build_catalog(
    machine: Dict[str, Any],
    fast: bool = True,
    which: Optional[Sequence[str]] = None,
    reps: Optional[int] = None,
    configs: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Measure + predict every requested kernel; returns the catalog dict."""
    import jax

    jax.config.update("jax_enable_x64", True)
    cfgs = configs or default_configs(fast)
    which = tuple(which) if which else KERNELS
    unknown = set(which) - set(KERNELS)
    if unknown:
        raise ValueError(f"unknown kernels {sorted(unknown)}; known: {KERNELS}")
    n_reps = reps or (3 if fast else 10)
    builders = {
        "gm_eval": gm_eval_entries,
        "advance": advance_entries,
        "vegas_iterate": vegas_entries,
        "service_dispatch": dispatch_entries,
    }
    entries: List[Dict[str, Any]] = []
    for kernel in which:
        entries.extend(
            predict(e, machine) for e in builders[kernel](cfgs[kernel], n_reps)
        )
    return {
        "machine": {
            "name": machine.get("name"),
            "source": machine.get("source"),
            "peak_flops": machine["peak_flops"],
            "mem_bw": machine["mem_bw"],
        },
        "entries": entries,
    }


def save_catalog(catalog: Dict[str, Any], path: str = DEFAULT_PATH) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(catalog, f, indent=1)
        f.write("\n")
    return path


def load_catalog(path: str = DEFAULT_PATH) -> Optional[Dict[str, Any]]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def render_table(entries: Sequence[Dict[str, Any]]) -> str:
    """Markdown table of a catalog's entries (shared with the report)."""
    head = (
        "| kernel | rung | d | GFLOP | MB | measured | predicted | "
        "roofline frac | dominant |\n|---|---|---|---|---|---|---|---|---|"
    )
    rows = [head]
    for e in entries:
        rung = "—" if e.get("rung") is None else str(e["rung"])
        rows.append(
            f"| {e['kernel']} | {rung} | {e['d']} | "
            f"{e['flops_total'] / 1e9:.3f} | {e['bytes_total'] / 1e6:.1f} | "
            f"{e['measured_s'] * 1e3:.2f} ms | {e['predicted_s'] * 1e3:.2f} ms | "
            f"{e['roofline_frac']:.3f} | {e['dominant']} |"
        )
    return "\n".join(rows)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Lower, cost, and time the repo's real kernels."
    )
    ap.add_argument("--out", default=DEFAULT_PATH)
    ap.add_argument(
        "--machine",
        default=None,
        help=f"machine file to predict from (default: {MACHINE_PATH} if "
        "present, else the v5e preset)",
    )
    ap.add_argument("--full", action="store_true", help="benchmark-scale shapes")
    ap.add_argument(
        "--only", default=None, help=f"comma-separated subset of {KERNELS}"
    )
    args = ap.parse_args(argv)

    machine = resolve_machine(args.machine)
    which = args.only.split(",") if args.only else None
    catalog = build_catalog(machine, fast=not args.full, which=which)
    path = save_catalog(catalog, args.out)
    print(render_table(catalog["entries"]))
    print(f"\nwrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
