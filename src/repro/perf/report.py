"""Performance report: one document for where the time goes.

``python -m repro.perf.report`` renders four sections into
``results/perf/PERF_REPORT.md`` (plus an ``.html`` twin):

1. **Machine** — the measured machine file (peak FLOP/s, memory bandwidth,
   probe details), or the documented preset when nothing was measured;
2. **Kernel cost catalog** — predicted-vs-measured roofline fractions per
   (kernel, rung, d) from :mod:`repro.perf.catalog`.  Fractions above 1
   mean the HLO byte count overstates true traffic for a cache-resident
   working set — expected for the small rungs on CPU;
3. **Benchmark trajectory** — every provenance-headed results file under
   ``results/benchmarks/`` (date, git SHA, device) and the normalized
   ``BENCH_summary.json`` metrics, so successive sweeps are comparable at
   a glance (the hard gate is :mod:`repro.perf.regress`);
4. **Service latency & idle** — when a telemetry metrics JSONL is supplied
   (``--metrics``): p50/p99 of the scheduler's per-dispatch wall-time and
   queue-wait histograms, plus per-device idle fractions from the
   ``service.n_live`` occupancy timeline (:mod:`repro.telemetry.loadview`).

Missing inputs degrade to a note in the section, never an error — the
report must render from whatever this checkout has.  If no catalog exists
yet one is built in fast mode first (a few minutes), so a bare
``python -m repro.perf.report`` on a fresh clone is self-sufficient.
"""

from __future__ import annotations

import html as html_lib
import json
import os
from typing import Any, Dict, List, Optional

from repro.perf import catalog as catalog_lib
from repro.perf import machine as machine_lib

_REPO = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
)
DEFAULT_OUT = os.path.join(_REPO, "results", "perf")
BENCH_DIR = os.path.join(_REPO, "results", "benchmarks")

#: scheduler latency histograms the report summarizes (DESIGN.md §9)
LATENCY_HISTS = ("service.dispatch_wall_s", "service.queue_wait_s")


def _fmt_si(x: Optional[float], unit: str) -> str:
    if x is None:
        return "—"
    for scale, prefix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= scale:
            return f"{x / scale:.2f} {prefix}{unit}"
    return f"{x:.2f} {unit}"


def machine_section(machine: Dict[str, Any]) -> List[str]:
    out = ["## Machine", ""]
    meta = machine.get("meta", {})
    src = machine.get("source", "unknown")
    if src == "preset":
        out.append(
            f"No measured machine file — using the **{machine.get('name')}**"
            " preset (vendor-sheet numbers). Run `python -m repro.perf.machine`"
            " to measure this device."
        )
        out.append("")
    else:
        out.append(
            f"Measured on platform `{meta.get('platform')}` "
            f"(`{meta.get('device_kind')}` x {meta.get('device_count')}, "
            f"jax {meta.get('jax_version')})."
        )
        out.append("")
    out.append("| term | value | probe |")
    out.append("|---|---|---|")
    probes = machine.get("probes", {})

    def probe_note(key: str) -> str:
        p = probes.get(key)
        if not p:
            return "preset"
        n = p.get("n", p.get("n_per_device"))
        return f"n={n}, best of reps: {p['seconds'] * 1e3:.1f} ms"

    out.append(
        f"| peak FLOP/s ({machine.get('working_dtype', 'f64')}) | "
        f"{_fmt_si(machine['peak_flops'], 'FLOP/s')} | "
        f"{probe_note('matmul_f64')} |"
    )
    if "matmul_f32" in probes:
        out.append(
            f"| peak FLOP/s (float32, reference) | "
            f"{_fmt_si(probes['matmul_f32']['flops_per_s'], 'FLOP/s')} | "
            f"{probe_note('matmul_f32')} |"
        )
    out.append(
        f"| memory bandwidth (saxpy) | {_fmt_si(machine['mem_bw'], 'B/s')} | "
        f"{probe_note('saxpy')} |"
    )
    if machine.get("reduce_bw"):
        out.append(
            f"| read bandwidth (reduction) | "
            f"{_fmt_si(machine['reduce_bw'], 'B/s')} | {probe_note('reduction')} |"
        )
    ici = machine.get("ici_bw")
    out.append(
        f"| inter-device bandwidth | {_fmt_si(ici, 'B/s') if ici else '— (1 device)'} | "
        f"{probe_note('ici_ppermute')} |"
    )
    out.append("")
    return out


def catalog_section(catalog: Dict[str, Any]) -> List[str]:
    out = ["## Kernel cost catalog", ""]
    m = catalog.get("machine", {})
    out.append(
        f"Predicted from machine `{m.get('name')}` "
        f"(peak {_fmt_si(m.get('peak_flops'), 'FLOP/s')}, "
        f"mem {_fmt_si(m.get('mem_bw'), 'B/s')}). `roofline frac` = predicted"
        " bound / measured wall time (1.0 = at the roofline; > 1 = the HLO"
        " byte count overstates true traffic, typical for cache-resident"
        " rungs). Scan-body counts are scaled by `scan_trips` (fused"
        " dispatch); see DESIGN.md §9."
    )
    out.append("")
    out.append(catalog_lib.render_table(catalog["entries"]))
    out.append("")
    return out


def bench_section(bench_dir: str) -> List[str]:
    out = ["## Benchmark trajectory", ""]
    if not os.path.isdir(bench_dir):
        out.append("_No results/benchmarks directory — run `python -m benchmarks.run`._")
        out.append("")
        return out
    names = sorted(
        f for f in os.listdir(bench_dir) if f.endswith(".json")
    )
    rows = ["| results file | date | git SHA | platform | device | records |",
            "|---|---|---|---|---|---|"]
    summary = None
    for name in names:
        path = os.path.join(bench_dir, name)
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            rows.append(f"| {name} | — | — | — | — | unreadable |")
            continue
        # pre-provenance results files are bare record lists (no meta header)
        meta = data.get("meta", {}) if isinstance(data, dict) else {}
        if name == "BENCH_summary.json" and isinstance(data, dict):
            summary = data
        if isinstance(data, dict):
            records = data.get("records", data.get("metrics"))
        else:
            records = data
        n = len(records) if isinstance(records, (list, dict)) else "?"
        rows.append(
            f"| {name} | {str(meta.get('date'))[:19]} | {meta.get('git_sha')} | "
            f"{meta.get('platform')} | {meta.get('device_kind')} "
            f"x{meta.get('device_count')} | {n} |"
        )
    out.extend(rows)
    out.append("")
    if summary is not None:
        out.append("### Tracked metrics (BENCH_summary.json)")
        out.append("")
        out.append("| metric | wall (us) |")
        out.append("|---|---|")
        for k, v in sorted(summary.get("metrics", {}).items()):
            out.append(f"| {k} | {float(v):.1f} |")
        out.append("")
        out.append(
            "_Gate: `python -m repro.perf.regress baseline.json candidate.json`"
            " (fail > 1.3x, warn > 1.1x)._"
        )
        out.append("")
    else:
        out.append(
            "_No BENCH_summary.json yet — `python -m benchmarks.run` emits it._"
        )
        out.append("")
    return out


def telemetry_section(metrics_path: Optional[str]) -> List[str]:
    out = ["## Service latency & idle", ""]
    if not metrics_path:
        out.append(
            "_No metrics JSONL supplied — serve with `--metrics m.jsonl` and"
            " re-run with `--metrics m.jsonl` for dispatch latency and idle"
            " fractions._"
        )
        out.append("")
        return out
    from repro.telemetry import quantile
    from repro.telemetry.loadview import (
        hist_values_from_events,
        idle_fraction,
        mean_imbalance,
        occupancy_from_events,
    )
    from repro.telemetry.sinks import read_jsonl

    events = read_jsonl(metrics_path)
    out.append(f"From `{metrics_path}` ({len(events)} events).")
    out.append("")
    out.append("| histogram | count | p50 | p99 | max |")
    out.append("|---|---|---|---|---|")
    for name in LATENCY_HISTS:
        vals = hist_values_from_events(events, name)
        if not vals:
            out.append(f"| {name} | 0 | — | — | — |")
            continue
        out.append(
            f"| {name} | {len(vals)} | {quantile(vals, 0.5) * 1e3:.2f} ms | "
            f"{quantile(vals, 0.99) * 1e3:.2f} ms | {max(vals) * 1e3:.2f} ms |"
        )
    out.append("")

    timeline = occupancy_from_events(events)
    if timeline.iterations:
        # slots/devices ride on the service.start event the scheduler emits
        slots = devices = None
        for e in events:
            if e.get("kind") == "instant" and e.get("name") == "service.start":
                slots, devices = e.get("slots"), e.get("devices")
                break
        if slots and devices:
            spd = int(slots) // int(devices)
            idle = idle_fraction(timeline, spd)
            out.append("| device | idle fraction |")
            out.append("|---|---|")
            for dev, frac in sorted(idle.items()):
                out.append(f"| {dev} | {frac:.3f} |")
            out.append("")
        out.append(
            f"Mean work imbalance (Fig. 4b `1 - mean/max`): "
            f"{mean_imbalance(timeline):.3f} over "
            f"{len(timeline.iterations)} iterations."
        )
        out.append("")
    else:
        out.append("_No `service.n_live` occupancy gauges in this stream._")
        out.append("")
    return out


def render_markdown(
    machine: Dict[str, Any],
    catalog: Dict[str, Any],
    bench_dir: str,
    metrics_path: Optional[str],
) -> str:
    lines: List[str] = ["# Performance report", ""]
    meta = machine_lib._collect_meta()
    lines.append(
        f"_Rendered on platform `{meta.get('platform')}`, jax "
        f"{meta.get('jax_version')}. Regenerate: `python -m repro.perf.report`._"
    )
    lines.append("")
    lines.extend(machine_section(machine))
    lines.extend(catalog_section(catalog))
    lines.extend(bench_section(bench_dir))
    lines.extend(telemetry_section(metrics_path))
    return "\n".join(lines).rstrip() + "\n"


def render_html(markdown: str) -> str:
    """Minimal standalone HTML twin (tables stay readable as markdown)."""
    return (
        "<!DOCTYPE html>\n<html><head><meta charset='utf-8'>"
        "<title>Performance report</title>"
        "<style>body{font-family:monospace;max-width:1100px;margin:2em auto;"
        "white-space:pre-wrap;}</style></head><body>"
        + html_lib.escape(markdown)
        + "</body></html>\n"
    )


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description="Render the performance report.")
    ap.add_argument("--machine", default=None, help="machine file path")
    ap.add_argument(
        "--catalog",
        default=catalog_lib.DEFAULT_PATH,
        help="kernel catalog path (built fast-mode if missing)",
    )
    ap.add_argument("--bench-dir", default=BENCH_DIR)
    ap.add_argument(
        "--metrics", default=None, help="telemetry metrics JSONL from a serve run"
    )
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    machine = machine_lib.resolve_machine(args.machine)
    catalog = catalog_lib.load_catalog(args.catalog)
    if catalog is None:
        print(f"no catalog at {args.catalog} — building one (fast mode)")
        catalog = catalog_lib.build_catalog(machine, fast=True)
        catalog_lib.save_catalog(catalog, args.catalog)

    md = render_markdown(machine, catalog, args.bench_dir, args.metrics)
    os.makedirs(args.out, exist_ok=True)
    md_path = os.path.join(args.out, "PERF_REPORT.md")
    html_path = os.path.join(args.out, "PERF_REPORT.html")
    with open(md_path, "w") as f:
        f.write(md)
    with open(html_path, "w") as f:
        f.write(render_html(md))
    print(f"wrote {md_path}")
    print(f"wrote {html_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
