"""Machine profiler: micro-benchmark the current device into a machine file.

A *machine file* is the measured half of the roofline model (DESIGN.md §9):
a small committed-able JSON document recording what the device this
container actually runs on can sustain —

- ``peak_flops``  — FLOP/s from a timed dense matmul in the working dtype
  (float64 here: the quadrature stack runs the paper's tolerances in f64;
  an f32 probe is recorded alongside for reference);
- ``mem_bw``      — bytes/s from a timed saxpy sweep (``y = a*x + y``:
  two reads + one write per element, the classic STREAM triad shape);
- ``reduce_bw``   — bytes/s from a timed full reduction (one read per
  element; reductions are the advance stage's dominant access pattern);
- ``ici_bw``      — bytes/s per link from a timed ``ppermute`` ring rotate
  when more than one device is visible, else ``None``.  On virtual CPU
  meshes this measures a host memcpy, which is still the honest number for
  what collectives cost *here*.

Every probe takes the best of ``reps`` timed repetitions — peak numbers
answer "what can the hardware do", so interference should push estimates
down, never up.

:data:`PRESETS` carries documented vendor-sheet fallbacks for hardware we
cannot measure from this container.  ``"v5e"`` is the exact constant set
``benchmarks/roofline.py`` used to hardcode (197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s ICI per link); a drift test pins the two to each other.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Optional

_REPO = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
)

#: default machine-file location (committed so CI and the report generator
#: share one baseline; re-run ``python -m repro.perf.machine`` to refresh)
DEFAULT_PATH = os.path.join(_REPO, "results", "perf", "machine.json")

#: Vendor-sheet presets for devices this container cannot measure.  The
#: ``"v5e"`` entry is the old hardcoded constant set of
#: ``benchmarks/roofline.py`` (bf16 peak per chip, HBM bandwidth, ICI
#: bandwidth per link) — kept bit-equal to those module constants by
#: ``tests/test_perf.py`` so the documented fallback can never drift.
PRESETS: Dict[str, Dict[str, Any]] = {
    "v5e": {
        "name": "v5e-preset",
        "source": "preset",
        "peak_flops": 197e12,
        "mem_bw": 819e9,
        "reduce_bw": 819e9,
        "ici_bw": 50e9,
    },
}


def _best_time(fn: Callable[[], Any], reps: int) -> float:
    """Best-of-``reps`` wall time of ``fn`` (one warmup call first)."""
    import jax

    jax.block_until_ready(fn())  # warmup: trace + compile + first dispatch
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_matmul(n: int, dtype, reps: int) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), dtype)
    b = jnp.ones((n, n), dtype)
    f = jax.jit(lambda x, y: x @ y)
    t = _best_time(lambda: f(a, b), reps)
    return {"n": n, "seconds": t, "flops_per_s": 2.0 * n**3 / t}


def _probe_saxpy(n: int, dtype, reps: int) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp

    x = jnp.ones((n,), dtype)
    y = jnp.ones((n,), dtype)
    f = jax.jit(lambda a, b: 2.0 * a + b)
    t = _best_time(lambda: f(x, y), reps)
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "n": n,
        "seconds": t,
        # two operand reads + one result write per element
        "bytes_per_s": 3.0 * n * itemsize / t,
    }


def _probe_reduction(n: int, dtype, reps: int) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp

    x = jnp.ones((n,), dtype)
    f = jax.jit(jnp.sum)
    t = _best_time(lambda: f(x), reps)
    return {"n": n, "seconds": t, "bytes_per_s": n * jnp.dtype(dtype).itemsize / t}


def _probe_ici(n: int, dtype, reps: int) -> Optional[Dict[str, float]]:
    """Ring-rotate an ``(n,)`` buffer across all visible devices.

    Returns ``None`` on a single device.  The per-link payload is the whole
    buffer (every device sends its shard to its neighbour simultaneously).
    """
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    if len(devs) < 2:
        return None
    from jax.sharding import PartitionSpec as P

    try:  # jax-version-compat shim, mirrors repro.core.distributed
        from jax.experimental.shard_map import shard_map as _shard_map
    except ImportError:  # pragma: no cover - newer jax
        _shard_map = jax.shard_map
    mesh = jax.make_mesh((len(devs),), ("probe",), devices=devs)
    perm = [(i, (i + 1) % len(devs)) for i in range(len(devs))]

    def rotate(x):
        return jax.lax.ppermute(x, "probe", perm)

    f = jax.jit(
        _shard_map(rotate, mesh=mesh, in_specs=P("probe"), out_specs=P("probe"))
    )
    x = jnp.ones((n * len(devs),), dtype)
    t = _best_time(lambda: f(x), reps)
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "n_per_device": n,
        "devices": len(devs),
        "seconds": t,
        "bytes_per_s": n * itemsize / t,
    }


def profile_machine(
    fast: bool = True,
    *,
    matmul_n: Optional[int] = None,
    stream_n: Optional[int] = None,
    reps: Optional[int] = None,
) -> Dict[str, Any]:
    """Measure the current device into a machine dict (see module docstring).

    ``fast`` picks probe sizes that finish in a few seconds on this CPU
    container; ``fast=False`` quadruples the working sets for steadier
    numbers.  The explicit size/rep overrides exist for tests.
    """
    import jax
    import jax.numpy as jnp

    mm_n = matmul_n or (768 if fast else 1536)
    st_n = stream_n or ((1 << 23) if fast else (1 << 25))
    n_reps = reps or (3 if fast else 10)

    f64 = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    matmul64 = _probe_matmul(mm_n, f64, n_reps)
    matmul32 = _probe_matmul(mm_n, jnp.float32, n_reps)
    saxpy = _probe_saxpy(st_n, f64, n_reps)
    reduction = _probe_reduction(st_n, f64, n_reps)
    ici = _probe_ici(min(st_n, 1 << 21), f64, n_reps)

    return {
        "name": "measured",
        "source": "measured",
        "meta": _collect_meta(),
        "working_dtype": str(jnp.dtype(f64)),
        "peak_flops": matmul64["flops_per_s"],
        "mem_bw": saxpy["bytes_per_s"],
        "reduce_bw": reduction["bytes_per_s"],
        "ici_bw": None if ici is None else ici["bytes_per_s"],
        "probes": {
            "matmul_f64": matmul64,
            "matmul_f32": matmul32,
            "saxpy": saxpy,
            "reduction": reduction,
            "ici_ppermute": ici,
        },
    }


def _collect_meta() -> Dict[str, Any]:
    """Provenance for a machine file (mirrors benchmarks/_common meta)."""
    meta: Dict[str, Any] = {
        "jax_version": None,
        "platform": None,
        "device_kind": None,
        "device_count": None,
    }
    try:
        import jax

        devices = jax.devices()
        meta["jax_version"] = jax.__version__
        meta["platform"] = devices[0].platform
        meta["device_kind"] = devices[0].device_kind
        meta["device_count"] = len(devices)
    except Exception:  # noqa: BLE001 — provenance must never fail a probe
        pass
    return meta


def save_machine(machine: Dict[str, Any], path: str = DEFAULT_PATH) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(machine, f, indent=1)
        f.write("\n")
    return path


def load_machine(path: str = DEFAULT_PATH) -> Optional[Dict[str, Any]]:
    """Load a machine file; ``None`` when absent (callers fall back)."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        machine = json.load(f)
    for key in ("peak_flops", "mem_bw"):
        if key not in machine:
            raise ValueError(
                f"{path} is not a machine file: missing {key!r} "
                "(regenerate with `python -m repro.perf.machine`)"
            )
    return machine


def resolve_machine(
    path: Optional[str] = None, preset: str = "v5e"
) -> Dict[str, Any]:
    """The machine terms to predict with: measured file if present, else
    the documented preset.

    This is the single resolution rule shared by the catalog, the report,
    and ``benchmarks/roofline.py``: an explicit ``path`` must exist (a typo
    silently falling back to v5e constants would poison every prediction);
    with no path the committed default file is used when present and the
    ``preset`` otherwise.
    """
    if path is not None:
        machine = load_machine(path)
        if machine is None:
            raise FileNotFoundError(
                f"machine file {path} not found; generate one with "
                "`python -m repro.perf.machine --out " + path + "`"
            )
        return machine
    machine = load_machine(DEFAULT_PATH)
    if machine is not None:
        return machine
    return dict(PRESETS[preset])


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Micro-benchmark this device into a machine file."
    )
    ap.add_argument("--out", default=DEFAULT_PATH)
    ap.add_argument("--full", action="store_true", help="larger probe sizes")
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)
    machine = profile_machine(fast=not args.full)
    path = save_machine(machine, args.out)
    ici = machine["ici_bw"]
    print(f"wrote {path}")
    print(
        f"  peak_flops = {machine['peak_flops']:.3e} FLOP/s  "
        f"mem_bw = {machine['mem_bw']:.3e} B/s  "
        f"reduce_bw = {machine['reduce_bw']:.3e} B/s  "
        f"ici_bw = {'n/a (1 device)' if ici is None else f'{ici:.3e} B/s'}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
