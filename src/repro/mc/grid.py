"""Per-axis VEGAS importance grid: piecewise-linear map + damped refinement.

The grid factorises the importance density as a product of per-axis 1-D
densities, each represented by ``n_bins`` equal-probability bins over the
unit interval (the classic VEGAS representation, Lepage 1978/2020): bin
``b`` of axis ``i`` maps the uniform slice ``[b/nb, (b+1)/nb)`` onto
``[edges[i, b], edges[i, b+1])``, so narrow bins concentrate samples and
the map's Jacobian ``nb * (edges[b+1] - edges[b])`` is exactly the
importance weight the estimator divides by.

Shape discipline (DESIGN.md §1 and §7): the grid is a fixed ``(d,
n_bins + 1)`` array of edges in ``[0, 1]`` — refinement moves the edges but
never their count, so every iteration of the MC engine is one XLA program.
Refinement is *damped* (Lepage's ``alpha`` compression of the per-bin
weights) so single-iteration noise cannot whipsaw the grid, and the per-bin
weights are smoothed over neighbours before the rebuild so isolated spikes
spread to the bins that would catch them next iteration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_edges(d: int, n_bins: int, dtype=jnp.float64) -> jnp.ndarray:
    """The identity grid: ``(d, n_bins + 1)`` uniformly spaced edges."""
    e = jnp.linspace(0.0, 1.0, n_bins + 1, dtype=dtype)
    return jnp.broadcast_to(e, (d, n_bins + 1))


def bin_index(y: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """Uniform coordinate ``y`` (d, N) in [0, 1) -> owning bin per axis."""
    return jnp.clip((y * n_bins).astype(jnp.int32), 0, n_bins - 1)


def apply_map(edges: jnp.ndarray, y: jnp.ndarray):
    """Map uniform ``y`` (d, N) through the grid.

    Returns ``(x01, jac)``: the mapped coordinates (d, N) in the unit cube
    and the total Jacobian ``prod_i nb * w_bin_i`` of shape (N,).  Sampling
    ``y`` uniformly and weighting by ``jac`` is importance sampling from the
    grid's product density.
    """
    d, nbp1 = edges.shape
    nb = nbp1 - 1
    b = bin_index(y, nb)
    frac = y * nb - b
    left = jnp.take_along_axis(edges, b, axis=1)
    right = jnp.take_along_axis(edges, b + 1, axis=1)
    w = right - left
    x01 = left + frac * w
    jac = jnp.prod(nb * w, axis=0)
    return x01, jac


def refine(edges: jnp.ndarray, dsum: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """One damped refinement step from accumulated per-bin weights.

    ``dsum`` (d, n_bins) is the iteration's accumulated importance measure
    per bin (the engine uses the sum of ``(f * jac)^2`` over samples landing
    in the bin).  Per axis: smooth over neighbours, normalise, compress with
    Lepage's damping ``r = ((1 - m) / ln(1/m))^alpha``, then rebuild the
    edges so every new bin holds equal compressed mass.  An axis with no
    accumulated mass keeps its current edges.
    """
    dtype = edges.dtype
    d, nbp1 = edges.shape
    nb = nbp1 - 1
    dsum = dsum.astype(dtype)

    # neighbour smoothing: (d_{i-1} + 6 d_i + d_{i+1}) / 8, reflective ends
    left = jnp.concatenate([dsum[:, :1], dsum[:, :-1]], axis=1)
    right = jnp.concatenate([dsum[:, 1:], dsum[:, -1:]], axis=1)
    sm = (left + 6.0 * dsum + right) / 8.0

    total = jnp.sum(sm, axis=1, keepdims=True)
    m = sm / jnp.where(total > 0.0, total, 1.0)
    # damping: m -> ((1 - m) / ln(1/m))^alpha, continuous limits 0 and 1
    mc = jnp.clip(m, 1e-99, 1.0 - 1e-15)
    r = ((1.0 - mc) / -jnp.log(mc)) ** alpha
    # strictly positive floor: a zero-mass bin must keep nonzero width, else
    # samples landing in it would map to a zero-measure x-slab (jac = 0) and
    # silently remove that slab from the integral
    r = jnp.maximum(r, 1e-10 * jnp.max(r, axis=1, keepdims=True))

    # rebuild: new edge j sits where the cumulative compressed mass crosses
    # j / nb of the axis total (piecewise-linear inverse CDF over old bins)
    cr = jnp.concatenate(
        [jnp.zeros((d, 1), dtype), jnp.cumsum(r, axis=1)], axis=1
    )  # (d, nb + 1), cr[:, -1] = axis total
    targets = cr[:, -1:] * (
        jnp.arange(1, nb, dtype=dtype) / nb
    )  # (d, nb - 1) interior targets
    find = jax.vmap(lambda c, t: jnp.searchsorted(c, t, side="right"))
    k = jnp.clip(find(cr, targets) - 1, 0, nb - 1).astype(jnp.int32)
    rk = jnp.take_along_axis(r, k, axis=1)
    frac = (targets - jnp.take_along_axis(cr, k, axis=1)) / rk
    lo = jnp.take_along_axis(edges, k, axis=1)
    wi = jnp.take_along_axis(edges, k + 1, axis=1) - lo
    interior = lo + jnp.clip(frac, 0.0, 1.0) * wi
    new_edges = jnp.concatenate(
        [jnp.zeros((d, 1), dtype), interior, jnp.ones((d, 1), dtype)], axis=1
    )
    # zero-mass axes (integrand identically zero there so far): keep edges
    keep = (total <= 0.0) | ~jnp.isfinite(total)
    return jnp.where(keep, edges, new_edges)
