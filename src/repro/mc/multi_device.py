"""Multi-device VEGAS: sample shards spread over the mesh.

MC is embarrassingly parallel, so the multi-device story is the clean
counterpoint to the cubature backend's region migration: no load balancing,
no payload exchange — each device evaluates ``mc_shards / n_devices`` of the
iteration's fixed sample shards under the repo's ``_shard_map`` shim, the
per-shard partial sums are all-gathered (device order == shard order) and
combined in the engine's fixed left-to-right scan, and the grid/counts
refinement runs replicated on every device from the identical combined
accumulators.

Because shards — not raw sample ranges — are the unit of division, and every
cross-shard reduction happens after the gather in a fixed order, the
estimate is **bit-identical to the single-device engine at any device count
dividing ``mc_shards``**, with device-count-invariant sample totals
(``cfg.mc_samples`` per iteration regardless of the mesh).  That parity is
asserted by the ``__main__`` selftest below, run in a subprocess by
``tests/test_mc.py`` (same idiom as ``repro.core.dist_selftest``: all jax
imports are deferred so the selftest can force the virtual device count
before the backend initialises).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.config import QuadratureConfig

AXIS = "dev"


def integrate_vegas_distributed(
    cfg: QuadratureConfig,
    integrand: Optional[Callable] = None,
    devices=None,
    callback: Optional[Callable[[int, float, float, float], None]] = None,
    recorder=None,
):
    """VEGAS with the sample shards sharded across ``devices`` (default all).

    Requires ``cfg.mc_shards % n_devices == 0``.  The state is replicated
    (it is a few KB of grid edges and scalars); only the sample evaluation
    is divided, which is where all the time goes.  Returns a
    :class:`~repro.mc.engine.VegasResult`.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import _shard_map
    from repro.mc.engine import (
        _resolve_serial_fn,
        drive,
        integrate_vegas,
        make_iterate,
    )

    from repro.telemetry import NULL

    recorder = NULL if recorder is None else recorder
    cfg = cfg.validate()
    devices = list(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    fn = _resolve_serial_fn(cfg, integrand)
    if n_dev == 1:
        return integrate_vegas(cfg, fn, callback, recorder=recorder)
    if cfg.mc_shards % n_dev:
        raise ValueError(
            f"mc_shards={cfg.mc_shards} must be divisible by the device "
            f"count ({n_dev}); shards are the unit of sample division"
        )
    mesh = jax.make_mesh((n_dev,), (AXIS,), devices=devices)
    body = make_iterate(cfg, fn, axis_name=AXIS, n_devices=n_dev)
    iterate = jax.jit(
        _shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))
    )
    return drive(cfg, iterate, callback, recorder=recorder)


def main() -> None:
    """Parity selftest: ``python -m repro.mc.multi_device [n_devices]``.

    Runs every case single-device and at each device count in
    ``{2, n_devices}``, asserting bit-identical integral/error and
    device-count-invariant eval totals; prints one JSON blob.
    """
    import json
    import os
    import sys

    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core.integrands import get as get_integrand
    from repro.mc.engine import integrate_vegas

    assert len(jax.devices()) == n_dev, jax.devices()
    counts = sorted({2, n_dev} - {1})

    out = {"n_devices": n_dev, "device_counts": [1] + counts, "cases": []}
    cases = [
        ("genz_gaussian:5,5,5:0.5,0.3,0.7", 3, 1e-4),
        ("f6", 3, 1e-3),
        ("f4", 5, 1e-3),
    ]
    for name, d, tol in cases:
        cfg = QuadratureConfig(
            d=d,
            integrand=name,
            rel_tol=tol,
            backend="vegas",
            mc_samples=4096,
            mc_max_iters=30,
        )
        single = integrate_vegas(cfg)
        rec = {
            "integrand": name,
            "d": d,
            "integral": single.integral,
            "error": single.error,
            "status": single.status,
            "n_evals": single.n_evals,
            "chi2_dof": single.chi2_dof,
            "parity": [],
        }
        exact = get_integrand(name).exact(d)
        rec["rel_err"] = abs(single.integral - exact) / max(abs(exact), 1e-300)
        for p in counts:
            dist = integrate_vegas_distributed(cfg, devices=jax.devices()[:p])
            bit_identical = (
                dist.integral == single.integral
                and dist.error == single.error
                and dist.n_evals == single.n_evals
                and dist.iterations == single.iterations
            )
            rec["parity"].append(
                {
                    "devices": p,
                    "integral": dist.integral,
                    "error": dist.error,
                    "bit_identical": bool(bit_identical),
                }
            )
            assert bit_identical, (
                f"{name} d={d}: {p}-device result diverged from single "
                f"device: {dist.integral!r} vs {single.integral!r} "
                f"(error {dist.error!r} vs {single.error!r})"
            )
        out["cases"].append(rec)
    print("RESULT_JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
