"""Stratified sample generation + VEGAS+ counts-per-hypercube adaptation.

The unit cube of *uniform* coordinates (the ``y``-space the importance grid
maps to ``x``) is divided into ``n_strat^d`` congruent hypercubes.  Each
iteration draws a fixed total of ``n_samples`` points, but the per-cube
counts adapt: cubes whose integrand (after importance weighting) still has
high variance receive more of the budget (Lepage 2020's VEGAS+ damped
``sigma^(2 beta)`` rule), which is what lets the estimator keep shrinking on
integrands whose structure the separable importance grid cannot represent.

Shape discipline: the sample array is a fixed ``(d, n_samples)`` block;
dynamic per-cube counts become a *cube-major* assignment — sample ``i``
belongs to the cube whose cumulative count interval contains ``i``
(``searchsorted`` over the cumulative counts) — so adaptation changes
values, never shapes.  Counts are integers allocated by cumulative
rounding, which conserves the total exactly and keeps every cube at the
``n_min`` floor (an empty cube would bias the stratified estimator: its
slab of the domain would simply go missing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def choose_n_strat(d: int, n_samples: int, n_min: int) -> int:
    """Stratifications per axis: the largest ``n`` whose ``n^d`` hypercubes
    still leave every cube ``2 * n_min`` samples (half the budget stays
    free for adaptive reallocation).  Always >= 1; in high dimension this
    collapses to 1 and stratification gracefully degrades to pure
    importance sampling."""
    n = 1
    while (n + 1) ** d * 2 * n_min <= n_samples:
        n += 1
    return n


def cube_digits(cube: jnp.ndarray, n_strat: int, d: int) -> jnp.ndarray:
    """Cube id (N,) -> per-axis stratification indices (d, N), base n_strat."""
    powers = n_strat ** np.arange(d, dtype=np.int64)  # axis 0 varies fastest
    return (cube[None, :] // jnp.asarray(powers, cube.dtype)[:, None]) % n_strat


def allocate_counts(
    weights: jnp.ndarray, n_samples: int, n_min: int
) -> jnp.ndarray:
    """Integer per-cube counts: ``n_min`` each + the rest ∝ ``weights``.

    Cumulative rounding distributes the ``n_samples - n_min * M`` spare
    samples: monotone in the cumulative weight, sums to the spare exactly,
    and never goes negative — so the total is conserved bit-exactly at any
    weight vector, including degenerate ones (all-zero weights fall back to
    uniform).
    """
    (m,) = weights.shape
    spare = n_samples - n_min * m
    total = jnp.sum(weights)
    w = jnp.where(total > 0.0, weights / jnp.where(total > 0.0, total, 1.0), 1.0 / m)
    cum = jnp.round(jnp.cumsum(w) * spare).astype(jnp.int32)
    # force the exact total (guards cumsum round-off in the last entry)
    cum = cum.at[-1].set(spare)
    extra = jnp.diff(jnp.concatenate([jnp.zeros((1,), jnp.int32), cum]))
    return n_min + jnp.maximum(extra, 0)


def sample_y(
    key, counts: jnp.ndarray, index: jnp.ndarray, n_strat: int, d: int, dtype
):
    """Stratified uniform coordinates for the samples at ``index``.

    ``index`` (Ns,) are *global* sample indices in ``[0, n_samples)`` —
    shards pass their own contiguous block, so the cube assignment (and
    therefore the estimate) is a function of the global index alone, never
    of how samples are divided across shards or devices.  Returns
    ``(y, cube)``: coordinates (d, Ns) uniform within each sample's cube,
    and the owning cube ids (Ns,).
    """
    cum = jnp.cumsum(counts)
    cube = jnp.searchsorted(cum, index, side="right").astype(jnp.int32)
    digits = cube_digits(cube, n_strat, d).astype(dtype)
    u = jax.random.uniform(key, (d, index.shape[0]), dtype)
    # keep y strictly inside the cube so bin_index never rounds across a
    # stratification boundary
    u = jnp.clip(u, 0.0, 1.0 - jnp.finfo(dtype).eps)
    y = (digits + u) / n_strat
    return y, cube


def adapt_weights(
    old: jnp.ndarray, var_per_cube: jnp.ndarray, beta: float
) -> jnp.ndarray:
    """Damped VEGAS+ count weights: ``sigma_k^(2 beta)``, EMA-smoothed.

    The new allocation weight is the per-cube variance measure compressed
    by ``beta`` (Lepage's damping: beta = 1 is proportional allocation,
    beta = 0 uniform), normalised, and averaged 50/50 with the previous
    weights so one noisy iteration cannot starve a cube.
    """
    w = jnp.maximum(var_per_cube, 0.0) ** beta
    total = jnp.sum(w)
    m = w.shape[0]
    w = jnp.where(total > 0.0, w / jnp.where(total > 0.0, total, 1.0), 1.0 / m)
    return 0.5 * old + 0.5 * w
