"""VEGAS+ adaptive importance-sampling Monte Carlo backend (DESIGN.md §7).

The high-dimensional counterpart to the deterministic cubature engine in
:mod:`repro.core`: per-axis importance grids with damped refinement
(:mod:`repro.mc.grid`), stratified sampling with VEGAS+ per-hypercube count
adaptation (:mod:`repro.mc.stratified`), a fixed-shape jitted iteration +
weighted-average estimator with a chi^2/dof guard (:mod:`repro.mc.engine`),
and bit-identical sample sharding across a device mesh
(:mod:`repro.mc.multi_device`).  Selected via
``QuadratureConfig(backend="vegas")`` (or ``"auto"``).
"""

from repro.mc.engine import (
    VegasBatchEngine,
    VegasResult,
    VegasState,
    integrate_vegas,
)

__all__ = [
    "VegasBatchEngine",
    "VegasResult",
    "VegasState",
    "integrate_vegas",
    "integrate_vegas_distributed",
]


def __getattr__(name):
    # Lazy so that ``python -m repro.mc.multi_device`` (the parity selftest,
    # which must set XLA_FLAGS before the jax backend initialises) does not
    # trigger runpy's double-import of the module it is about to execute.
    if name == "integrate_vegas_distributed":
        from repro.mc.multi_device import integrate_vegas_distributed

        return integrate_vegas_distributed
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
