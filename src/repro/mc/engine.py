"""VEGAS+ adaptive importance-sampling engine (the repo's second backend).

One MC *iteration* is a single jitted program with fixed shapes (the same
shape discipline as the cubature engine, DESIGN.md §1):

1. **sample** — ``cfg.mc_samples`` stratified points: the unit cube of
   uniform coordinates is cut into ``n_strat^d`` hypercubes with adaptive
   per-cube counts (:mod:`repro.mc.stratified`), and each point is pushed
   through the per-axis importance grid (:mod:`repro.mc.grid`), picking up
   the map's Jacobian;
2. **evaluate** — the integrand (a plain ``f((d, N)) -> (N,)`` callable, a
   registry entry, or a theta-parameterized family from
   ``core/integrands.py``) at the mapped points;
3. **accumulate** — per-stratum sums of ``f·J`` and ``(f·J)^2`` give the
   iteration estimate ``I_t`` and its variance ``sigma_t^2``; per-axis
   per-bin sums of ``(f·J)^2`` feed the grid;
4. **refine** — damped grid refinement + VEGAS+ count reallocation.

Across iterations the estimator is the standard inverse-variance weighted
average ``I = sum(I_t / s_t^2) / sum(1 / s_t^2)`` with a chi^2/dof guard:
when the per-iteration estimates are mutually inconsistent (chi^2/dof > 1,
the classic symptom of an undersampled spike or a discontinuity) the
reported error is inflated by ``sqrt(chi^2/dof)`` so it stays a covering
estimate.  The first ``cfg.mc_warmup`` iterations adapt only — their
estimates are discarded, exactly as in Lepage's reference implementation.

**Sharded reduction layout.**  All sample reductions run in
``cfg.mc_shards`` fixed independent shards (each shard owns a contiguous
block of global sample indices and a PRNG key folded from the shard id),
and the shard partials are combined in a fixed left-to-right scan.  The
multi-device driver (:mod:`repro.mc.multi_device`) assigns whole shards to
devices and all-gathers the partials, so its estimates are *bit-identical*
to the single-device engine at any device count dividing ``mc_shards`` —
the clean embarrassingly-parallel counterpoint to the region-migration
story of the cubature backend.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptiveResult
from repro.core.config import QuadratureConfig
from repro.core.integrands import (
    ParamIntegrand,
    get as get_integrand,
    get_param,
)
from repro.mc import grid as grid_lib, stratified
from repro.telemetry import NULL

# A result needs at least this many accumulated (post-warmup) iterations
# before it may report convergence: with one sample the weighted average has
# no internal consistency check (chi^2 needs a dof), so a lucky first
# iteration cannot end the run on an untrustworthy error bar.
MIN_ACCUMULATED = 2


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "edges",
        "strat_w",
        "key",
        "sum_wi",
        "sum_w",
        "sum_wi2",
        "n_acc",
        "it",
        "n_evals",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class VegasState:
    """Fixed-shape MC state: importance grid + stratification + estimator."""

    edges: jnp.ndarray  # (d, mc_bins + 1) importance-grid edges in [0, 1]
    strat_w: jnp.ndarray  # (M,) damped per-cube allocation weights
    key: jnp.ndarray  # PRNG key; advances once per iteration
    sum_wi: jnp.ndarray  # sum I_t / sigma_t^2 over accumulated iterations
    sum_w: jnp.ndarray  # sum 1 / sigma_t^2
    sum_wi2: jnp.ndarray  # sum I_t^2 / sigma_t^2 (chi^2 bookkeeping)
    n_acc: jnp.ndarray  # int32 accumulated (post-warmup) iterations
    it: jnp.ndarray  # int32 iterations run (incl. warmup)
    n_evals: jnp.ndarray  # float — integrand evaluations spent


@dataclasses.dataclass
class VegasResult(AdaptiveResult):
    """MC result; ``error`` is the chi^2-inflated weighted-average sigma."""

    chi2_dof: float = 0.0

    def summary(self) -> str:
        return (
            f"I={self.integral:.15e} eps={self.error:.3e} [{self.status}] "
            f"iters={self.iterations} evals={self.n_evals:.3g} "
            f"chi2/dof={self.chi2_dof:.2f}"
        )


def mc_layout(cfg: QuadratureConfig) -> tuple[int, int]:
    """Static stratification layout ``(n_strat, n_cubes)`` for ``cfg``."""
    n_strat = stratified.choose_n_strat(
        cfg.d, cfg.mc_samples, cfg.mc_min_per_cube
    )
    return n_strat, n_strat**cfg.d


def init_state(cfg: QuadratureConfig) -> VegasState:
    dtype = jnp.dtype(cfg.dtype)
    _, m = mc_layout(cfg)
    return VegasState(
        edges=grid_lib.uniform_edges(cfg.d, cfg.mc_bins, dtype),
        strat_w=jnp.full((m,), 1.0 / m, dtype),
        key=jax.random.PRNGKey(cfg.mc_seed),
        sum_wi=jnp.zeros((), dtype),
        sum_w=jnp.zeros((), dtype),
        sum_wi2=jnp.zeros((), dtype),
        n_acc=jnp.zeros((), jnp.int32),
        it=jnp.zeros((), jnp.int32),
        n_evals=jnp.zeros((), dtype),
    )


def _ordered_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Left-to-right sum over the leading (shard) axis.

    A plain ``jnp.sum`` may be re-associated differently by XLA in the
    single- and multi-device programs; an explicit scan pins the reduction
    order so shard partials combine bit-identically in both.
    """
    init = jnp.zeros_like(x[0])
    out, _ = jax.lax.scan(lambda acc, row: (acc + row, None), init, x)
    return out


def make_iterate(
    cfg: QuadratureConfig,
    fn: Callable[..., jnp.ndarray],
    *,
    has_theta: bool = False,
    axis_name: Optional[str] = None,
    n_devices: int = 1,
) -> Callable:
    """Build the jittable single-iteration update.

    Returns ``iterate(state[, theta]) -> (state, metrics)`` with metrics
    ``{integral, error, chi2_dof, n_acc, it_integral, it_sigma}`` — the
    combined weighted-average estimate (falling back to the current
    iteration's during warmup) plus the per-iteration values.

    ``axis_name`` switches the shard loop into its multi-device form: each
    device runs ``mc_shards / n_devices`` shards and the partials are
    all-gathered (in device = shard order) before the fixed-order combine,
    which keeps the result bit-identical to the single-device engine.
    """
    cfg = cfg.validate()
    d = cfg.d
    nb = cfg.mc_bins
    n_strat, M = mc_layout(cfg)
    N = cfg.mc_samples
    S = cfg.mc_shards
    Ns = N // S
    dtype = jnp.dtype(cfg.dtype)
    lo = jnp.asarray(cfg.lo(), dtype)
    width = jnp.asarray(cfg.hi(), dtype) - lo
    volume = jnp.prod(width)
    if axis_name is not None and S % n_devices:
        raise ValueError(
            f"mc_shards={S} must be divisible by the device count "
            f"({n_devices}): shards are the unit of multi-device division"
        )
    local_shards = S // n_devices

    def shard_accumulate(shard_ix, sub, edges, counts, theta):
        """All sample work for one shard: returns per-cube and per-bin
        partial sums, bitwise a function of (shard_ix, sub, grid, counts)
        alone."""
        index = shard_ix * Ns + jnp.arange(Ns, dtype=jnp.int32)
        skey = jax.random.fold_in(sub, shard_ix)
        y, cube = stratified.sample_y(skey, counts, index, n_strat, d, dtype)
        x01, jac = grid_lib.apply_map(edges, y)
        x = lo[:, None] + width[:, None] * x01
        val = fn(x, theta) if has_theta else fn(x)
        w = val.astype(dtype) * jac * volume
        w2 = w * w
        s1 = jax.ops.segment_sum(w, cube, num_segments=M)
        s2 = jax.ops.segment_sum(w2, cube, num_segments=M)
        b = grid_lib.bin_index(y, nb)  # (d, Ns)
        flat = (jnp.arange(d, dtype=jnp.int32)[:, None] * nb + b).reshape(-1)
        g = jax.ops.segment_sum(
            jnp.broadcast_to(w2, (d, Ns)).reshape(-1), flat, num_segments=d * nb
        )
        return s1, s2, g

    def iterate(state: VegasState, theta=None):
        key, sub = jax.random.split(state.key)
        counts = stratified.allocate_counts(
            state.strat_w, N, cfg.mc_min_per_cube
        )
        if axis_name is None:
            shard_ids = jnp.arange(S, dtype=jnp.int32)
        else:
            base = jax.lax.axis_index(axis_name) * local_shards
            shard_ids = base.astype(jnp.int32) + jnp.arange(
                local_shards, dtype=jnp.int32
            )
        partials = jax.vmap(
            shard_accumulate, in_axes=(0, None, None, None, None)
        )(shard_ids, sub, state.edges, counts, theta)
        if axis_name is not None:
            # device order == shard order, so the gathered (S, ...) arrays
            # are exactly what the single-device vmap produces
            partials = jax.tree.map(
                lambda a: jax.lax.all_gather(a, axis_name, axis=0, tiled=True),
                partials,
            )
        s1, s2, g = (_ordered_sum(p) for p in partials)

        # --- per-stratum non-finite quarantine -------------------------------
        # A NaN/Inf integrand value poisons its stratum's partial sums, and
        # from there the iteration estimate, the weighted-average accumulator
        # and the grid refinement.  Zero the poisoned strata (and grid bins)
        # out of the estimate and flag the iteration: the drivers terminate
        # the problem with status "nonfinite" carrying the best-effort
        # estimate of the surviving strata.  For finite integrands the masks
        # are all-False and every where() is a bitwise identity.
        bad_k = ~(jnp.isfinite(s1) & jnp.isfinite(s2))
        # corrupted *accumulators* (e.g. a fault-injected slot) are equally
        # terminal: the weighted average can never recover a finite value
        bad_acc = ~(
            jnp.isfinite(state.sum_wi)
            & jnp.isfinite(state.sum_w)
            & jnp.isfinite(state.sum_wi2)
        )
        nonfinite = jnp.any(bad_k) | bad_acc
        s1 = jnp.where(bad_k, 0.0, s1)
        s2 = jnp.where(bad_k, 0.0, s2)
        g = jnp.where(jnp.isfinite(g), g, 0.0)

        nk = counts.astype(dtype)
        mean = s1 / nk
        i_t = jnp.sum(mean) / M
        var_k = jnp.maximum(s2 / nk - mean * mean, 0.0)
        sig2_t = jnp.sum(var_k / (nk - 1.0)) / (M * M)
        # round-off floor: an exactly-representable integrand (zero sample
        # variance) must not produce an infinite weight
        eps = jnp.finfo(dtype).eps
        sig2_t = jnp.maximum(sig2_t, (eps * (jnp.abs(i_t) + 1e-30)) ** 2)

        # --- adapt -----------------------------------------------------------
        edges = grid_lib.refine(state.edges, g.reshape(d, nb), cfg.mc_alpha)
        strat_w = stratified.adapt_weights(state.strat_w, var_k, cfg.mc_beta)

        # --- accumulate the weighted-average estimator -----------------------
        acc = state.it >= cfg.mc_warmup
        inv = jnp.where(acc, 1.0 / sig2_t, 0.0)
        sum_w = state.sum_w + inv
        sum_wi = state.sum_wi + i_t * inv
        sum_wi2 = state.sum_wi2 + i_t * i_t * inv
        n_acc = state.n_acc + acc.astype(jnp.int32)

        have = n_acc > 0
        safe_w = jnp.where(have, sum_w, 1.0)
        integral = jnp.where(have, sum_wi / safe_w, i_t)
        sigma = jnp.where(have, jnp.sqrt(1.0 / safe_w), jnp.sqrt(sig2_t))
        chi2 = jnp.maximum(sum_wi2 - sum_wi * sum_wi / safe_w, 0.0)
        dof = jnp.maximum(n_acc - 1, 1).astype(dtype)
        chi2_dof = jnp.where(n_acc > 1, chi2 / dof, jnp.zeros((), dtype))
        error = sigma * jnp.sqrt(jnp.maximum(1.0, chi2_dof))

        new_state = VegasState(
            edges=edges,
            strat_w=strat_w,
            key=key,
            sum_wi=sum_wi,
            sum_w=sum_w,
            sum_wi2=sum_wi2,
            n_acc=n_acc,
            it=state.it + 1,
            n_evals=state.n_evals + jnp.asarray(float(N), dtype),
        )
        metrics = {
            "integral": integral,
            "error": error,
            "chi2_dof": chi2_dof,
            "n_acc": n_acc,
            "it_integral": i_t,
            "it_sigma": jnp.sqrt(sig2_t),
            "nonfinite": nonfinite,
        }
        return new_state, metrics

    return iterate


def _resolve_serial_fn(
    cfg: QuadratureConfig, integrand: Optional[Callable]
) -> Callable:
    """Integrand for the serial drivers: explicit callable wins, else the
    config-named registry entry / family spec (theta bound in a closure —
    there is no Pallas-operand constraint on the MC path)."""
    if integrand is not None:
        return integrand
    return get_integrand(cfg.integrand).fn


def converged_now(
    cfg: QuadratureConfig, integral: float, error: float, n_acc: int
) -> bool:
    """The shared MC convergence predicate (host loop + batch pool)."""
    budget = max(cfg.abs_tol, abs(integral) * cfg.rel_tol)
    return n_acc >= MIN_ACCUMULATED and error <= budget


def drive(
    cfg: QuadratureConfig,
    iterate: Callable,
    callback: Optional[Callable[[int, float, float, float], None]] = None,
    recorder=NULL,
) -> VegasResult:
    """The shared host loop: run ``iterate`` (any jitted form of
    :func:`make_iterate` — serial or shard_map'd) to convergence or the
    iteration cap, one scalar sync per iteration.

    ``recorder`` (host-side only, see DESIGN.md §8) gets one ``mc.iterate``
    span plus an ``mc.iter`` instant per iteration — the per-iteration
    chi²/dof the estimator's consistency guard runs on, the accumulated
    count, and the achieved samples/s — and a one-shot ``mc.config``
    instant carrying the grid-damping knobs (``mc_alpha``/``mc_beta``).
    """
    state = init_state(cfg)
    integral = error = chi2 = 0.0
    converged = False
    nonfinite = False
    recorder.event(
        "mc.config",
        samples=cfg.mc_samples,
        bins=cfg.mc_bins,
        shards=cfg.mc_shards,
        warmup=cfg.mc_warmup,
        alpha=cfg.mc_alpha,
        beta=cfg.mc_beta,
    )
    for _ in range(cfg.mc_max_iters):
        t0 = time.perf_counter()
        with recorder.span("mc.iterate"):
            state, m = iterate(state)
            integral, error, chi2, n_acc = (
                float(m["integral"]),
                float(m["error"]),
                float(m["chi2_dof"]),
                int(m["n_acc"]),
            )
        if recorder.enabled:
            dt = time.perf_counter() - t0
            recorder.event(
                "mc.iter",
                it=int(state.it),
                integral=integral,
                error=error,
                chi2_dof=chi2,
                n_acc=n_acc,
            )
            recorder.gauge(
                "mc.samples_per_s", cfg.mc_samples / max(dt, 1e-9)
            )
        if callback is not None:
            callback(int(state.it), integral, error, chi2)
        if bool(m["nonfinite"]):
            # poisoned strata were quarantined inside the iterate; the
            # combined estimate is best-effort, so stop here rather than
            # keep averaging over a hole in the integrand
            nonfinite = True
            break
        if converged_now(cfg, integral, error, n_acc):
            converged = True
            break

    status = "converged" if converged else "max_iters"
    return VegasResult(
        integral=integral,
        error=error,
        status="nonfinite" if nonfinite else status,
        iterations=int(state.it),
        n_evals=float(state.n_evals),
        n_active=0,
        overflowed=False,
        chi2_dof=chi2,
    )


def integrate_vegas(
    cfg: QuadratureConfig,
    integrand: Optional[Callable] = None,
    callback: Optional[Callable[[int, float, float, float], None]] = None,
    recorder=NULL,
) -> VegasResult:
    """Host-driven VEGAS loop: one jitted iteration, one scalar sync each.

    Convergence matches the cubature drivers' budget —
    ``error <= max(abs_tol, |I| * rel_tol)`` — on the weighted-average
    estimate, with the chi^2-inflated error and a two-iteration minimum so
    the error bar always has an internal consistency check behind it.
    """
    cfg = cfg.validate()
    fn = _resolve_serial_fn(cfg, integrand)
    return drive(cfg, jax.jit(make_iterate(cfg, fn)), callback, recorder=recorder)


# --- the service pool: B independent VEGAS problems in lockstep --------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "mc",
        "theta",
        "rel_tol",
        "abs_tol",
        "occupied",
        "done",
        "admit_seq",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class VegasBatchState:
    """Stacked :class:`VegasState` + per-slot masks (leading (B,) axis)."""

    mc: VegasState
    theta: Any
    rel_tol: jnp.ndarray
    abs_tol: jnp.ndarray
    occupied: jnp.ndarray
    done: jnp.ndarray
    admit_seq: jnp.ndarray  # (B,) int32 admissions seen per slot (keys PRNG)


def _select_slots(mask: jnp.ndarray, new, old):
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


class VegasBatchEngine:
    """MC twin of :class:`repro.service.batch_engine.BatchEngine`.

    Drives ``cfg.batch_slots`` independent VEGAS problems of one integrand
    family through a vmapped iterate, with the same slot protocol the
    scheduler speaks (``init`` / ``admit`` / ``release`` / fused ``run``
    with early exit on a done-flip), so the continuous-batching service
    admits MC-backed requests through the identical host loop.

    The pool is single-device: MC parallelism lives at the *sample* level
    (:mod:`repro.mc.multi_device` shards one problem's shards over the
    mesh), not the slot level — a vmapped fleet already saturates a device,
    and slots converge on wall-clock-similar schedules (every slot costs
    ``mc_samples`` evaluations per iteration, unlike cubature's wildly
    varying live populations).
    """

    backend = "vegas"

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        mesh=None,
        devices=None,
        recorder=None,
    ):
        cfg = cfg.validate()
        if family is None:
            family = cfg.integrand.partition(":")[0]
        if isinstance(family, str):
            family = get_param(family)
        if mesh is not None or (devices is not None and len(devices) > 1) or (
            devices is None and mesh is None and cfg.service_devices not in (0, 1)
        ):
            raise ValueError(
                "the vegas service pool is single-device (slots are vmapped); "
                "MC multi-device parallelism shards samples instead — see "
                "repro.mc.multi_device.integrate_vegas_distributed"
            )
        self.cfg = cfg
        self.family = family
        self.n_slots = cfg.batch_slots
        self.mesh = None
        self.n_devices = 1
        self.slots_per_device = self.n_slots
        self.theta_template = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float64),
            family.sample_theta(cfg.d, np.random.default_rng(0)),
        )
        self.recorder = NULL if recorder is None else recorder
        self._dtype = jnp.dtype(cfg.dtype)
        self._base_key = jax.random.PRNGKey(cfg.mc_seed)
        with self.recorder.span(
            "engine.build",
            backend=self.backend,
            slots=self.n_slots,
            devices=self.n_devices,
        ):
            self._viterate = jax.vmap(
                make_iterate(cfg, family.fn, has_theta=True)
            )
            self._run = jax.jit(self._make_run())
            self._admit = jax.jit(self._make_admit())
            self._release = jax.jit(self._make_release())

    # --- state ---------------------------------------------------------------

    def init(self) -> VegasBatchState:
        cfg = self.cfg
        B = self.n_slots
        one = init_state(cfg)
        return VegasBatchState(
            mc=jax.tree.map(
                lambda x: jnp.broadcast_to(x, (B,) + x.shape).copy(), one
            ),
            theta=jax.tree.map(
                lambda x: jnp.zeros((B,) + x.shape, self._dtype),
                self.theta_template,
            ),
            rel_tol=jnp.full((B,), cfg.rel_tol, self._dtype),
            abs_tol=jnp.full((B,), cfg.abs_tol, self._dtype),
            occupied=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool),
            admit_seq=jnp.zeros((B,), jnp.int32),
        )

    def place(self, state):
        """Re-place a full logical fleet state on this (single-device) engine.

        Protocol parity with :meth:`BatchEngine.place`; the MC fleet's slot
        axis is never mesh-sharded (samples are, inside the iterate), so this
        is a plain host-to-device transfer.
        """
        return jax.tree.map(jnp.asarray, state)

    def _make_admit(self):
        fresh = init_state(self.cfg)
        base_key = self._base_key

        def admit(state: VegasBatchState, slot, theta, rel_tol, abs_tol):
            seq = state.admit_seq[slot] + 1
            key = jax.random.fold_in(jax.random.fold_in(base_key, slot), seq)
            slot_state = dataclasses.replace(fresh, key=key)
            put = lambda dst, src: dst.at[slot].set(src)
            return dataclasses.replace(
                state,
                mc=jax.tree.map(put, state.mc, slot_state),
                theta=jax.tree.map(put, state.theta, theta),
                rel_tol=put(state.rel_tol, rel_tol),
                abs_tol=put(state.abs_tol, abs_tol),
                occupied=put(state.occupied, True),
                done=put(state.done, False),
                admit_seq=state.admit_seq.at[slot].set(seq),
            )

        return admit

    def _make_release(self):
        def release(state: VegasBatchState, slot):
            return dataclasses.replace(
                state,
                occupied=state.occupied.at[slot].set(False),
                done=state.done.at[slot].set(False),
            )

        return release

    def admit(
        self,
        state: VegasBatchState,
        slot: int,
        theta,
        rel_tol: Optional[float] = None,
        abs_tol: Optional[float] = None,
    ) -> VegasBatchState:
        self._check_slot(slot)
        got = jax.tree.map(lambda x: np.shape(x), theta)
        want = jax.tree.map(lambda x: np.shape(x), self.theta_template)
        if got != want:
            raise ValueError(
                f"theta shape mismatch for family {self.family.name!r}: "
                f"got {got}, want {want}"
            )
        cfg = self.cfg
        return self._admit(
            state,
            jnp.asarray(slot, jnp.int32),
            jax.tree.map(lambda x: jnp.asarray(x, self._dtype), theta),
            jnp.asarray(cfg.rel_tol if rel_tol is None else rel_tol, self._dtype),
            jnp.asarray(cfg.abs_tol if abs_tol is None else abs_tol, self._dtype),
        )

    def release(self, state: VegasBatchState, slot: int) -> VegasBatchState:
        self._check_slot(slot)
        return self._release(state, jnp.asarray(slot, jnp.int32))

    def _check_slot(self, slot: int) -> None:
        if not 0 <= int(slot) < self.n_slots:
            raise IndexError(f"slot {slot} out of range [0, {self.n_slots})")

    # --- the fused dispatch --------------------------------------------------

    def _make_run(self):
        cfg = self.cfg
        viterate = self._viterate
        dtype = self._dtype

        def no_moves():
            return jnp.full((0, 2), -1, jnp.int32)

        def zero_metrics(B):
            z = jnp.zeros
            return {
                "integral": z((B,), dtype),
                "error": z((B,), dtype),
                "n_active": z((B,), jnp.int32),
                "it": z((B,), jnp.int32),
                "n_evals": z((B,), dtype),
                "overflowed": z((B,), bool),
                "converged": z((B,), bool),
                "nonfinite": z((B,), bool),
                "done": z((B,), bool),
                "occupied": z((B,), bool),
                "window": z((), jnp.int32),
            }

        def one_iter(state: VegasBatchState):
            live = state.occupied & ~state.done
            new_mc, m = viterate(state.mc, state.theta)
            mc = _select_slots(live, new_mc, state.mc)
            budget = jnp.maximum(
                state.abs_tol, jnp.abs(m["integral"]) * state.rel_tol
            )
            converged = (m["error"] <= budget) & (
                m["n_acc"] >= MIN_ACCUMULATED
            )
            capped = mc.it >= cfg.mc_max_iters
            nonfinite = live & m["nonfinite"]
            done = state.done | (live & (converged | capped)) | nonfinite
            n_new = jnp.sum(done & ~state.done).astype(jnp.int32)
            metrics = {
                "integral": m["integral"],
                "error": m["error"],
                "n_active": jnp.zeros_like(mc.n_acc),
                "it": mc.it,
                "n_evals": mc.n_evals,
                "overflowed": jnp.zeros(state.done.shape, bool),
                "converged": converged,
                "nonfinite": nonfinite,
                "done": done,
                "occupied": state.occupied,
                "window": jnp.zeros((), jnp.int32),
            }
            return dataclasses.replace(state, mc=mc, done=done), metrics, n_new

        def run_body(state: VegasBatchState, max_steps, tick):
            B = state.occupied.shape[0]

            def one(carry, t):
                state, stop = carry
                go = (~stop) & (t < max_steps)

                def do(state):
                    state, metrics, n_new = one_iter(state)
                    return state, metrics, no_moves(), n_new > 0

                def skip(state):
                    return state, zero_metrics(B), no_moves(), jnp.asarray(True)

                state, m, moved, stop = jax.lax.cond(go, do, skip, state)
                return (state, stop), (m, moved, go)

            (state, _), (ms, moved, executed) = jax.lax.scan(
                one,
                (state, jnp.asarray(False)),
                jnp.arange(cfg.sync_every, dtype=jnp.int32),
            )
            return state, ms, executed, moved

        return run_body

    def run(self, state: VegasBatchState, max_steps: int, tick: int):
        """Same contract as :meth:`BatchEngine.run` (``moved`` is empty)."""
        return self._run(
            state,
            jnp.asarray(min(int(max_steps), self.cfg.sync_every), jnp.int32),
            jnp.asarray(tick, jnp.int32),
        )

    def status_of(
        self,
        converged: bool,
        n_active: int,
        it: int,
        overflowed: bool,
        nonfinite: bool = False,
    ) -> str:
        """MC terminal taxonomy: no region store, so no capacity/no_active."""
        if nonfinite:
            return "nonfinite"
        if converged:
            return "converged"
        if it >= self.cfg.mc_max_iters:
            return "max_iters"
        return "running"
