"""Service-level checkpoint/resume for the continuous-batching scheduler.

A service snapshot is two artifacts, written in a strict order:

1. the stacked engine state (a :class:`~repro.service.batch_engine.BatchState`
   or :class:`~repro.mc.engine.VegasBatchState` pytree), saved atomically via
   :class:`repro.checkpoint.manager.CheckpointManager` (tmp-dir + fsync'd
   manifest + rename, CRC32 per leaf);
2. a ``meta_XXXXXXXX.json`` sidecar holding everything the *host* loop needs
   to replay: the slot -> request map (thetas round-trip bit-exactly through
   JSON's float64 repr), per-slot admission iterations, the iteration/tick
   counters, the host-loop stats, and the set of request ids already pulled
   from the queue.

The meta sidecar is written *after* the state and renamed into place
atomically, so its presence commits the snapshot: restore picks the newest
step for which both artifacts exist, and a crash between the two writes
leaves a harmless orphaned state directory behind the previous complete
snapshot.

Resume parity: snapshots are taken at admission-tick boundaries, right after
the tick's admissions.  From that point the scheduler's decisions are a pure
function of (engine state, slot map, iteration counter, remaining queue) —
all captured above — so a resumed run replays the original
decision-for-decision and reproduces bit-identical results for every slot
the crash did not touch.
"""

from __future__ import annotations

import json
import os
import re
from typing import Optional

import jax

from repro.checkpoint.manager import CheckpointManager

_META_RE = re.compile(r"^meta_(\d{8})\.json$")

#: Keys the scheduler's resume path reads from a meta sidecar.  A sidecar
#: missing any of them is treated as corrupt (same fallback as a JSON parse
#: failure): a partial write that happens to be valid JSON must not restore.
_REQUIRED_META = ("it", "ticks", "stats", "pulled_ids", "slots")


class ServiceCheckpointer:
    """Snapshot/restore the full serving state of a :class:`BatchScheduler`.

    ``save`` is synchronous on the state write (the engine donates its state
    buffers into the next fused dispatch, so the snapshot must be on disk —
    or at least copied to host, which ``CheckpointManager.save`` does before
    returning — by the time the scheduler resumes the loop).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.manager = CheckpointManager(os.path.join(directory, "state"), keep=keep)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state, meta: dict) -> None:
        """Write one snapshot: state first, then the committing meta sidecar."""
        self.manager.save(step, state, blocking=True)
        final = os.path.join(self.dir, f"meta_{step:08d}.json")
        tmp = final + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, **meta}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        """Drop meta sidecars whose state the manager has already GC'd."""
        keep = set(self.manager.all_steps())
        for name in os.listdir(self.dir):
            m = _META_RE.match(name)
            if m and int(m.group(1)) not in keep:
                os.unlink(os.path.join(self.dir, name))

    # -- restore --------------------------------------------------------------

    def complete_steps(self) -> list[int]:
        """Steps with both artifacts on disk (the restorable snapshots)."""
        metas = set()
        for name in os.listdir(self.dir):
            m = _META_RE.match(name)
            if m:
                metas.add(int(m.group(1)))
        return sorted(metas & set(self.manager.all_steps()))

    def latest_step(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def _read_meta(self, step: int) -> dict:
        """Load + validate one meta sidecar (raises on corrupt/partial)."""
        with open(os.path.join(self.dir, f"meta_{step:08d}.json")) as f:
            meta = json.load(f)
        missing = [k for k in _REQUIRED_META if k not in meta]
        if missing:
            raise KeyError(
                f"meta sidecar for step {step} is missing keys {missing}"
            )
        return meta

    def restore(self, engine, step: Optional[int] = None):
        """Rebuild ``(state, meta)`` for ``engine`` from the newest snapshot.

        ``engine.init()`` supplies both the pytree structure and the current
        placement: leaves are re-placed with the live state's shardings, so a
        restore works across device counts (the manager loads full logical
        arrays and re-shards).

        A snapshot whose artifacts turn out to be unreadable — a truncated
        meta sidecar surviving the ``os.replace`` on a dirty filesystem, a
        CRC-failing state leaf — is skipped and the newest *previous*
        complete snapshot restores instead; only when every snapshot is
        unreadable (or an explicit ``step`` was requested) does the error
        propagate.
        """
        like = engine.init()
        shardings = jax.tree.map(lambda x: x.sharding, like)
        state, meta, _ = self._restore_any(like, shardings, step)
        return state, meta

    def restore_host(self, like, step: Optional[int] = None):
        """``(state, meta, step)`` from the newest readable snapshot, without
        re-placing the state on any mesh.

        ``like`` only supplies the expected pytree structure/shapes (the live
        host copy of the engine state works).  Used by the scheduler's
        device-loss evacuation, which patches individual slot rows on the
        host before re-placing the whole state on the surviving sub-mesh.
        """
        return self._restore_any(like, None, step)

    def _restore_any(self, like, shardings, step: Optional[int]):
        if step is not None:
            meta = self._read_meta(step)
            state, _ = self.manager.restore(like, step=step, shardings=shardings)
            return state, meta, step
        steps = self.complete_steps()
        if not steps:
            raise FileNotFoundError(f"no complete service snapshot in {self.dir}")
        errors = []
        for s in reversed(steps):
            try:
                meta = self._read_meta(s)
                state, _ = self.manager.restore(like, step=s, shardings=shardings)
                return state, meta, s
            except (json.JSONDecodeError, KeyError, OSError) as err:
                errors.append(f"step {s}: {type(err).__name__}: {err}")
        raise FileNotFoundError(
            f"no readable service snapshot in {self.dir} "
            f"({len(steps)} present, all corrupt): " + "; ".join(errors)
        )
