"""Batch quadrature service: continuous batching for fleets of integrals,
sharded across the device mesh.

Layers (bottom up):

- :mod:`repro.service.batch_engine` — a vmapped adaptive step over a stacked
  region store (leading problem axis), per-slot convergence masks, one
  compiled executable per window rung shared by the whole batch; the slot
  axis shards over a device mesh (each device owns a contiguous block and
  runs the step locally), fleet-wide progress is decided from a psum of
  per-slot done masks once per fused ``sync_every`` dispatch, and drained
  devices pull whole problems from their cyclic ring partner (the paper's
  round-robin redistribution, lifted from regions to problems);
- :mod:`repro.service.scheduler` — the continuous-batching loop: a request
  queue feeding batch slots, mid-flight admission into slots freed by
  converged problems (targeting the device that owns the freed slot),
  eviction of capacity-saturated slots; every dispatch runs under a
  device-loss watchdog that retries transient faults and, on permanent
  failure, evacuates the dead device's slots and rebuilds the engine on
  the surviving sub-mesh (regrowing later — elastic fleet resilience,
  DESIGN.md §6);
- :mod:`repro.service.routing` — graceful degradation: fallback re-routing
  of degraded requests (capacity/nonfinite evictions to the VEGAS pool,
  tolerance-starved requests to a relaxed retry) with attempt provenance;
- :mod:`repro.service.checkpoint` — service-level snapshot/resume (engine
  state + slot map) on top of :mod:`repro.checkpoint`;
- :mod:`repro.service.faults` — deterministic fault injectors, exercised by
  ``python -m repro.service.chaos_selftest``;
- :mod:`repro.service.api` — ``integrate_batch`` / ``serve`` entry points.

Results are bit-identical at every device count, for every terminal status.
"""

from repro.service.api import integrate_batch, serve
from repro.service.batch_engine import BatchEngine, BatchState
from repro.service.checkpoint import ServiceCheckpointer
from repro.service.routing import GracefulScheduler, ReroutePolicy
from repro.service.scheduler import (
    BatchScheduler,
    DeviceLostError,
    DispatchTimeout,
    QuadRequest,
    QuadResult,
)

__all__ = [
    "BatchEngine",
    "BatchScheduler",
    "BatchState",
    "DeviceLostError",
    "DispatchTimeout",
    "GracefulScheduler",
    "QuadRequest",
    "QuadResult",
    "ReroutePolicy",
    "ServiceCheckpointer",
    "integrate_batch",
    "serve",
]
