"""Batch quadrature service: continuous batching for fleets of integrals.

Layers (bottom up):

- :mod:`repro.service.batch_engine` — a vmapped adaptive step over a stacked
  region store (leading problem axis), per-slot convergence masks, one
  compiled executable per window rung shared by the whole batch;
- :mod:`repro.service.scheduler` — the continuous-batching loop: a request
  queue feeding batch slots, mid-flight admission into slots freed by
  converged problems, eviction of capacity-saturated slots;
- :mod:`repro.service.api` — ``integrate_batch`` / ``serve`` entry points.
"""

from repro.service.api import integrate_batch, serve
from repro.service.batch_engine import BatchEngine, BatchState
from repro.service.scheduler import BatchScheduler, QuadRequest, QuadResult

__all__ = [
    "BatchEngine",
    "BatchScheduler",
    "BatchState",
    "QuadRequest",
    "QuadResult",
    "integrate_batch",
    "serve",
]
