"""Fallback re-routing: retry degraded requests on the backend that can serve them.

The paper's robustness claim is about *finishing the job*: the adaptive
solver keeps converging where a GPU-tailored baseline breaks down.  The
service-level analogue is that a terminal-but-unconverged request should not
simply be reported as a failure when another engine pool can still produce a
converged estimate:

- a cubature slot evicted as ``capacity`` hit region-store saturation — the
  signature of a high-dimensional / low-regularity problem that importance-
  sampling MC handles without a region store (cuVegas regime), so it is
  re-admitted once to the VEGAS pool;
- a ``nonfinite`` quarantine may be caused by cubature's deterministic node
  placement hitting a pole; the VEGAS pool samples different points and may
  miss it (and if the integrand is NaN everywhere, the retry quarantines
  again and the request is reported ``nonfinite`` with its provenance);
- a VEGAS request that exhausts ``max_iters`` without meeting its tolerance
  is retried once at a relaxed tolerance, trading accuracy for an answer.

Every retry consumes the request's attempt budget; the final
:class:`~repro.service.scheduler.QuadResult` carries the provenance
(``backend``, ``attempts``, ``retried_from``) so callers can tell a
first-try estimate from a degraded one.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Optional, Union

from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.scheduler import BatchScheduler, QuadRequest, QuadResult
from repro.telemetry import NULL, ServiceStats


@dataclasses.dataclass(frozen=True)
class ReroutePolicy:
    """When and how a terminal-but-degraded request earns another attempt.

    ``max_attempts`` bounds total admissions per request (1 = never retry).
    ``reroute_statuses`` re-admit a cubature request to the VEGAS pool;
    ``relax_statuses`` re-admit to the *same* backend with tolerances
    loosened by ``tol_relax``.
    """

    max_attempts: int = 2
    reroute_statuses: tuple = ("capacity", "nonfinite")
    relax_statuses: tuple = ("max_iters",)
    tol_relax: float = 10.0

    def validate(self) -> "ReroutePolicy":
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.tol_relax < 1.0:
            raise ValueError(f"tol_relax must be >= 1, got {self.tol_relax}")
        return self


class GracefulScheduler:
    """A :class:`BatchScheduler` with fallback re-routing.

    Serves the request stream through the primary pool, then re-admits
    degraded requests (per :class:`ReroutePolicy`) to fallback pools:
    cubature ``capacity``/``nonfinite`` evictions to a single-device VEGAS
    pool, tolerance-starved requests to a relaxed-tolerance pass on their own
    backend.  Results that need no retry are yielded as soon as the primary
    pool collects them; retried requests are yielded after their final
    attempt, with provenance filled in.

    ``last_stats`` aggregates the host-loop counters of every pool —
    field-wise over the shared :class:`~repro.telemetry.ServiceStats`
    schema, so a counter added to one pool can no longer silently vanish
    from the aggregate — plus ``reroutes`` (fallback re-admissions, both
    kinds).  ``recorder`` is shared with every pool; re-admissions emit
    ``service.reroute`` flow events (drawn as arrows in the Chrome trace).

    Elastic-fleet kwargs (``fault_injector``, ``max_dispatch_retries``,
    ``dispatch_timeout_s``) pass through ``scheduler_kwargs`` to the
    *primary* pool only: the fallback VEGAS pool is single-device by
    construction, so device-loss recovery does not apply to it, and a
    retry pass after a shrink simply runs on the primary's surviving
    sub-mesh.  ``evacuated`` provenance survives re-routing.
    """

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        mesh=None,
        devices=None,
        policy: Optional[ReroutePolicy] = None,
        recorder=NULL,
        **scheduler_kwargs,
    ):
        self.policy = (policy or ReroutePolicy()).validate()
        self.recorder = recorder
        self.primary = BatchScheduler(
            cfg,
            family,
            mesh=mesh,
            devices=devices,
            recorder=recorder,
            **scheduler_kwargs,
        )
        self.cfg = self.primary.cfg
        self.family = self.primary.engine.family
        self._vegas_pool: Optional[BatchScheduler] = None
        self._stats = ServiceStats()

    @property
    def last_stats(self) -> dict:
        """Dict view of the latest run's aggregated stats (compat)."""
        return self._stats.as_dict()

    def _vegas(self) -> BatchScheduler:
        """The fallback MC pool, built lazily (it compiles its own fleet)."""
        if self._vegas_pool is None:
            cfg = dataclasses.replace(
                self.cfg, backend="vegas", service_devices=1
            )
            self._vegas_pool = BatchScheduler(
                cfg, self.family, recorder=self.recorder
            )
        return self._vegas_pool

    def serve(
        self, requests: Iterable[QuadRequest], resume: bool = False
    ) -> Iterator[QuadResult]:
        policy = self.policy
        rec = self.recorder
        stats = ServiceStats()
        self._stats = stats

        def merge(pool_stats: dict) -> None:
            # field-wise over the typed schema: an unknown key coming back
            # from a pool is a loud error, not a silently dropped counter
            stats.merge(ServiceStats.from_dict(pool_stats))

        def record_reroutes(results: list, to_backend: str) -> None:
            stats.add("reroutes", len(results))
            rec.count("service.reroutes", len(results))
            if rec.enabled:
                for r in results:
                    rec.flow(
                        "service.reroute",
                        None,
                        None,
                        req_id=r.req_id,
                        from_status=r.status,
                        to_backend=to_backend,
                    )

        by_id: dict[int, QuadRequest] = {}

        def recording(stream):
            for req in stream:
                by_id[req.req_id] = req
                yield req

        primary_backend = self.primary.engine.backend
        reroute: list[QuadResult] = []  # cubature -> vegas pool
        relax: list[QuadResult] = []  # same backend, loosened tolerances
        for res in self.primary.serve(recording(requests), resume=resume):
            if policy.max_attempts > 1 and res.status in policy.relax_statuses:
                relax.append(res)
            elif (
                policy.max_attempts > 1
                and primary_backend == "cubature"
                and res.status in policy.reroute_statuses
            ):
                reroute.append(res)
            else:
                yield res
        merge(self.primary.last_stats)

        # Fallback passes run after the primary fleet drains: the retry
        # population is tiny by construction (degraded requests only), so a
        # dedicated small pass beats holding primary slots hostage.  Each
        # pool's serve() builds fresh state, so reusing a scheduler is free.
        if reroute:
            record_reroutes(reroute, "vegas")
            prior = {r.req_id: r for r in reroute}
            pool = self._vegas()
            for res in pool.serve([by_id[r.req_id] for r in reroute]):
                # a request evacuated off a failed device in the prior
                # attempt keeps that provenance through the re-route
                yield dataclasses.replace(
                    res,
                    attempts=prior[res.req_id].attempts + 1,
                    retried_from=prior[res.req_id].status,
                    evacuated=res.evacuated or prior[res.req_id].evacuated,
                )
            merge(pool.last_stats)

        if relax:
            record_reroutes(relax, primary_backend)
            prior = {r.req_id: r for r in relax}
            cfg = self.cfg
            retries = [
                dataclasses.replace(
                    by_id[r.req_id],
                    rel_tol=(
                        cfg.rel_tol
                        if by_id[r.req_id].rel_tol is None
                        else by_id[r.req_id].rel_tol
                    )
                    * policy.tol_relax,
                    abs_tol=(
                        cfg.abs_tol
                        if by_id[r.req_id].abs_tol is None
                        else by_id[r.req_id].abs_tol
                    )
                    * policy.tol_relax,
                )
                for r in relax
            ]
            for res in self.primary.serve(retries):
                yield dataclasses.replace(
                    res,
                    attempts=prior[res.req_id].attempts + 1,
                    retried_from=prior[res.req_id].status,
                    evacuated=res.evacuated or prior[res.req_id].evacuated,
                )
            merge(self.primary.last_stats)
