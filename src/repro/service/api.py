"""Entry points for the batch quadrature service.

Two shapes of the same engine:

- :func:`integrate_batch` — the *offline* form: hand it a fleet of thetas,
  get the full list of results back in submission order (a drop-in batched
  analogue of calling :func:`repro.core.adaptive.integrate` in a loop);
- :func:`serve` — the *online* form: hand it any iterable (or generator) of
  :class:`QuadRequest`\\ s and consume :class:`QuadResult`\\ s as they
  converge.  Requests are pulled lazily, so an unbounded stream
  backpressures on slot availability — this is the continuous-batching
  surface a real service would sit behind.
"""

from __future__ import annotations

from typing import Any, Iterable, Iterator, Optional, Sequence, Union

import numpy as np

from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.scheduler import BatchScheduler, QuadRequest, QuadResult


def _as_theta_list(thetas: Union[Sequence[Any], Any]) -> list[Any]:
    """Normalise ``thetas`` to a list of per-problem pytrees.

    Accepts either a sequence of theta dicts (one per problem) or a single
    *stacked* dict whose leaves carry a leading batch axis (the natural
    output of vectorised theta generation).
    """
    if isinstance(thetas, dict):
        leaves = {k: np.asarray(v) for k, v in thetas.items()}
        sizes = {v.shape[0] for v in leaves.values()}
        if len(sizes) != 1:
            raise ValueError(
                f"stacked theta leaves disagree on batch size: { {k: v.shape for k, v in leaves.items()} }"
            )
        (b,) = sizes
        return [{k: v[i] for k, v in leaves.items()} for i in range(b)]
    return list(thetas)


def serve(
    cfg: QuadratureConfig,
    requests: Iterable[QuadRequest],
    family: Union[ParamIntegrand, str, None] = None,
    mesh=None,
    devices=None,
    graceful: bool = False,
    resume: bool = False,
    **scheduler_kwargs,
) -> Iterator[QuadResult]:
    """Stream results for an arbitrary request iterable (convergence order).

    ``graceful=True`` serves through
    :class:`~repro.service.routing.GracefulScheduler`: degraded requests
    (capacity/nonfinite evictions, tolerance-starved retries) are re-routed
    per the default :class:`~repro.service.routing.ReroutePolicy` instead of
    being reported as failures.  ``resume=True`` restores the latest service
    snapshot before serving (requires a ``checkpointer``).  Extra keyword
    arguments (``checkpointer``, ``checkpoint_every``, ``on_tick``,
    ``recorder`` — a :class:`repro.telemetry.Recorder` for structured
    telemetry — and for the graceful form ``policy``) pass through to the
    scheduler.
    """
    if graceful:
        from repro.service.routing import GracefulScheduler

        sched = GracefulScheduler(
            cfg, family, mesh=mesh, devices=devices, **scheduler_kwargs
        )
    else:
        sched = BatchScheduler(
            cfg, family, mesh=mesh, devices=devices, **scheduler_kwargs
        )
    return sched.serve(requests, resume=resume)


def integrate_batch(
    cfg: QuadratureConfig,
    thetas: Union[Sequence[Any], Any],
    family: Union[ParamIntegrand, str, None] = None,
    rel_tol: Union[float, Sequence[float], None] = None,
    abs_tol: Union[float, Sequence[float], None] = None,
    mesh=None,
    devices=None,
) -> list[QuadResult]:
    """Integrate a fleet of problems; results in submission order.

    ``thetas`` is a list of theta pytrees (or one stacked pytree with a
    leading batch axis); ``rel_tol`` / ``abs_tol`` may be scalars applied to
    every problem, per-problem sequences, or ``None`` for the ``cfg``
    defaults.  ``family`` defaults to the family named by ``cfg.integrand``
    (its spec prefix before the first ``:``).

    ``mesh`` / ``devices`` shard the slot axis across a device mesh (see
    :class:`~repro.service.batch_engine.BatchEngine`); results are
    bit-identical at every device count.
    """
    theta_list = _as_theta_list(thetas)
    n = len(theta_list)

    def per_problem(tol, name) -> list[Optional[float]]:
        if tol is None or np.ndim(tol) == 0:
            return [None if tol is None else float(tol)] * n
        if len(tol) != n:
            raise ValueError(f"{name} has {len(tol)} entries for {n} problems")
        return [float(t) for t in tol]

    rels = per_problem(rel_tol, "rel_tol")
    abss = per_problem(abs_tol, "abs_tol")
    requests = [
        QuadRequest(req_id=i, theta=t, rel_tol=r, abs_tol=a)
        for i, (t, r, a) in enumerate(zip(theta_list, rels, abss))
    ]
    results: list[Optional[QuadResult]] = [None] * n
    for res in serve(cfg, requests, family, mesh=mesh, devices=devices):
        results[res.req_id] = res
    missing = [i for i, r in enumerate(results) if r is None]
    if missing:  # pragma: no cover - invariant guard
        raise RuntimeError(f"scheduler dropped requests {missing}")
    return results
