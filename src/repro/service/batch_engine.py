"""Batched adaptive quadrature engine: one compiled step for B problems.

The single-problem drivers in :mod:`repro.core.adaptive` solve one integral
per invocation.  Fleets of *related* integrals ``∫ f(x; theta_k) dx`` over a
shared domain (parameter sweeps, Bayesian evidence grids, PDF convolutions)
instead run here: the SoA :class:`~repro.core.region_store.RegionState` gains
a leading problem axis and the whole adaptive step — windowed rule
evaluation, classification, split/compact — is ``vmap``-ped across it, so
the fleet shares one XLA program and the hardware sees one big batch of
regions instead of B small ones.

Heterogeneous convergence across the fleet is the same load-imbalance
problem the paper solves across devices; here it is solved across batch
slots by *continuous batching* (the idiom of the LLM serving engine in
``repro.serving``): per-slot ``done`` masks turn converged problems into
pass-throughs, and the scheduler splices a fresh initial partition into a
freed slot mid-flight (:func:`~repro.core.region_store.write_slot`) without
recompilation.

Window discipline: the eval window must be a single static shape per
dispatch, so the engine picks the smallest ladder rung covering the *widest*
live slot (``lax.switch`` at the top level, each branch the vmapped eval at
one rung).  By the active-window invariance argument (any window >=
n_active is exact) every slot gets bit-identical estimates to its own
serial run at that rung — there is exactly one compiled executable per
(d, rule, window-rung), shared across the whole batch.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import region_store
from repro.core.adaptive import (
    donate_argnums,
    eval_ladder,
    make_advance_step,
    make_eval_step,
)
from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand, get_param
from repro.core.region_store import RegionState
from repro.core.rules import make_rule


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "regions",
        "theta",
        "rel_tol",
        "abs_tol",
        "occupied",
        "done",
        "overflow_it",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class BatchState:
    """B independent problems in lockstep: stacked stores + per-slot masks."""

    regions: RegionState  # every leaf has a leading (B,) axis
    theta: Any  # family theta pytree, leaves (B, d)
    rel_tol: jnp.ndarray  # (B,) per-request tolerances
    abs_tol: jnp.ndarray  # (B,)
    occupied: jnp.ndarray  # (B,) bool — slot holds an admitted problem
    done: jnp.ndarray  # (B,) bool — result ready, frozen until released
    overflow_it: jnp.ndarray  # (B,) int32 — it at first overflow, -1 = never

    @property
    def n_slots(self) -> int:
        return self.occupied.shape[0]


def _select_slots(mask: jnp.ndarray, new, old):
    """Per-slot select over a stacked pytree (mask broadcast over trailing dims)."""

    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


class BatchEngine:
    """Compiled-step executor for a fixed-shape fleet of one integrand family.

    All problems share ``cfg``'s static shape (d, capacity, rule, domain) and
    differ only in theta and tolerances — that is what makes the batch a
    single XLA program.  The scheduler (:mod:`repro.service.scheduler`)
    drives :meth:`step` from the host, admitting and collecting per slot.
    """

    def __init__(
        self, cfg: QuadratureConfig, family: Union[ParamIntegrand, str, None] = None
    ):
        cfg = cfg.validate()
        if cfg.use_kernel:
            raise ValueError(
                "the batch engine does not support the Pallas kernel path: "
                "family integrands close over per-slot theta arrays, which "
                "pallas_call rejects as captured constants; set "
                "use_kernel=False (the jnp reference rule vmaps fine)"
            )
        if family is None:
            family = cfg.integrand.partition(":")[0]
        if isinstance(family, str):
            family = get_param(family)
        self.cfg = cfg
        self.family = family
        self.n_slots = cfg.batch_slots

        lo = np.asarray(cfg.lo(), np.float64)
        hi = np.asarray(cfg.hi(), np.float64)
        self._total_volume = float(np.prod(hi - lo))
        self._width = hi - lo
        self._dtype = jnp.dtype(cfg.dtype)
        # fresh single-slot state spliced into a slot on admit
        self._fresh_slot = region_store.init_state(
            cfg.capacity, lo, hi, cfg.resolved_n_init(), self._dtype
        )
        # theta template fixes the pytree structure + leaf shapes of the fleet
        self.theta_template = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float64),
            family.sample_theta(cfg.d, np.random.default_rng(0)),
        )

        donate = donate_argnums()
        self._step = jax.jit(self._make_step(), donate_argnums=donate)
        self._admit = jax.jit(self._make_admit(), donate_argnums=donate)
        self._release = jax.jit(self._make_release(), donate_argnums=donate)

    # --- state construction --------------------------------------------------

    def init(self) -> BatchState:
        """All slots empty; admit problems before stepping."""
        cfg = self.cfg
        B = self.n_slots
        return BatchState(
            regions=region_store.stacked_empty_state(
                B, cfg.capacity, cfg.d, self._dtype
            ),
            theta=jax.tree.map(
                lambda x: jnp.zeros((B,) + x.shape, self._dtype),
                self.theta_template,
            ),
            rel_tol=jnp.full((B,), cfg.rel_tol, self._dtype),
            abs_tol=jnp.full((B,), cfg.abs_tol, self._dtype),
            occupied=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool),
            overflow_it=jnp.full((B,), -1, jnp.int32),
        )

    # --- jitted slot operations ----------------------------------------------

    def _make_admit(self):
        fresh = self._fresh_slot

        def admit(state: BatchState, slot, theta, rel_tol, abs_tol) -> BatchState:
            return dataclasses.replace(
                state,
                regions=region_store.write_slot(state.regions, slot, fresh),
                theta=jax.tree.map(
                    lambda dst, src: dst.at[slot].set(src), state.theta, theta
                ),
                rel_tol=state.rel_tol.at[slot].set(rel_tol),
                abs_tol=state.abs_tol.at[slot].set(abs_tol),
                occupied=state.occupied.at[slot].set(True),
                done=state.done.at[slot].set(False),
                overflow_it=state.overflow_it.at[slot].set(-1),
            )

        return admit

    def _make_release(self):
        def release(state: BatchState, slot) -> BatchState:
            return dataclasses.replace(
                state,
                occupied=state.occupied.at[slot].set(False),
                done=state.done.at[slot].set(False),
            )

        return release

    def admit(
        self,
        state: BatchState,
        slot: int,
        theta,
        rel_tol: Optional[float] = None,
        abs_tol: Optional[float] = None,
    ) -> BatchState:
        """Write a fresh initial partition + theta into ``slot`` (mid-flight safe)."""
        self._check_slot(slot)
        got = jax.tree.map(lambda x: np.shape(x), theta)
        want = jax.tree.map(lambda x: np.shape(x), self.theta_template)
        if got != want:
            raise ValueError(
                f"theta shape mismatch for family {self.family.name!r}: "
                f"got {got}, want {want}"
            )
        return self._admit(
            state,
            jnp.asarray(slot, jnp.int32),
            jax.tree.map(lambda x: jnp.asarray(x, self._dtype), theta),
            jnp.asarray(self.cfg.rel_tol if rel_tol is None else rel_tol, self._dtype),
            jnp.asarray(self.cfg.abs_tol if abs_tol is None else abs_tol, self._dtype),
        )

    def release(self, state: BatchState, slot: int) -> BatchState:
        """Free a collected slot (its store stays stale until the next admit)."""
        self._check_slot(slot)
        return self._release(state, jnp.asarray(slot, jnp.int32))

    def _check_slot(self, slot: int) -> None:
        # JAX drops out-of-bounds scatter updates, so a bad index would
        # otherwise no-op silently and strand the request.
        if not 0 <= int(slot) < self.n_slots:
            raise IndexError(f"slot {slot} out of range [0, {self.n_slots})")

    # --- the batched adaptive step -------------------------------------------

    def _make_step(self):
        cfg = self.cfg
        family = self.family
        total_volume = self._total_volume
        ladder = eval_ladder(cfg)
        rungs = jnp.asarray(ladder, jnp.int32)

        def eval_branch(window: int):
            def eval_one(regions: RegionState, theta) -> RegionState:
                rule = make_rule(cfg, lambda x: family.fn(x, theta))
                return make_eval_step(cfg, rule, window=window)(regions)

            return jax.vmap(eval_one)

        branches = [eval_branch(w) for w in ladder]

        # the serial drivers' advance, vmapped with per-slot traced tolerances
        advance = jax.vmap(make_advance_step(cfg, total_volume, self._width))

        def step(state: BatchState):
            live = state.occupied & ~state.done
            counts = jnp.sum(state.regions.active, axis=1).astype(jnp.int32)
            widest = jnp.max(jnp.where(live, counts, 0))
            ix = region_store.rung_index(rungs, widest)

            evald = jax.lax.switch(ix, branches, state.regions, state.theta)
            regions = _select_slots(live, evald, state.regions)

            integral, error = jax.vmap(lambda r: r.global_estimates())(regions)
            budget = jnp.maximum(state.abs_tol, jnp.abs(integral) * state.rel_tol)
            n_active = jnp.sum(regions.active, axis=1).astype(jnp.int32)
            converged = error <= budget
            # Capacity pressure is not instantly terminal: the serial driver
            # grinds past overflow and often converges, so an overflowed slot
            # keeps refining for ``evict_patience`` further iterations (exact
            # serial parity for transient saturation) before being evicted.
            overflow_it = jnp.where(
                regions.overflowed & (state.overflow_it < 0),
                regions.it,
                state.overflow_it,
            )
            evicted = regions.overflowed & (
                regions.it - overflow_it >= cfg.evict_patience
            )
            # The serial driver runs exactly max_iters eval sweeps: post-eval
            # ``it == max_iters - 1`` means this sweep was the last one, so
            # the slot freezes NOW — checking ``it >= max_iters`` instead
            # would eval the final advance's children one extra time and
            # break bit-parity with `integrate` on the max_iters path.
            capped = regions.it >= cfg.max_iters - 1
            terminal = converged | (n_active == 0) | capped | evicted
            done = state.done | (live & terminal)

            advanced = advance(regions, budget, state.rel_tol)
            regions = _select_slots(state.occupied & ~done, advanced, regions)
            # Serial parity on the counter too: after capturing its final
            # metrics the serial driver still runs (and counts) one advance
            # before the loop exhausts.  The frozen slot skips the splitting
            # (its estimates must stay collectable) but mirrors the counter.
            bump = live & capped & ~converged & (n_active > 0)
            regions = dataclasses.replace(
                regions, it=regions.it + bump.astype(regions.it.dtype)
            )

            metrics = {
                "integral": integral,
                "error": error,
                "n_active": n_active,
                "it": regions.it,
                "n_evals": regions.n_evals,
                "overflowed": regions.overflowed,
                "converged": converged,
                "done": done,
                "occupied": state.occupied,
                "window": rungs[ix],
            }
            return (
                dataclasses.replace(
                    state, regions=regions, done=done, overflow_it=overflow_it
                ),
                metrics,
            )

        return step

    def step(self, state: BatchState):
        """One fused iteration for every live slot; returns (state, metrics).

        ``metrics`` holds per-slot device arrays: ``integral``, ``error``,
        ``n_active``, ``it``, ``n_evals``, ``overflowed``, ``converged``,
        ``done``, ``occupied`` plus the scalar eval ``window`` used.  Slots
        whose ``done`` flips on are frozen (no further advance) until the
        scheduler collects and releases them.
        """
        return self._step(state)
