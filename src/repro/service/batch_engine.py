"""Batched adaptive quadrature engine: one compiled step for B problems,
sharded across the device mesh.

The single-problem drivers in :mod:`repro.core.adaptive` solve one integral
per invocation.  Fleets of *related* integrals ``∫ f(x; theta_k) dx`` over a
shared domain (parameter sweeps, Bayesian evidence grids, PDF convolutions)
instead run here: the SoA :class:`~repro.core.region_store.RegionState` gains
a leading problem axis and the whole adaptive step — windowed rule
evaluation, classification, split/compact — is ``vmap``-ped across it, so
the fleet shares one XLA program and the hardware sees one big batch of
regions instead of B small ones.

Heterogeneous convergence across the fleet is the same load-imbalance
problem the paper solves across devices, and here both axes compose:

- *across batch slots* — continuous batching (the idiom of the LLM serving
  engine in ``repro.serving``): per-slot ``done`` masks turn converged
  problems into pass-throughs and the scheduler splices a fresh initial
  partition into a freed slot mid-flight
  (:func:`~repro.core.region_store.write_slot`) without recompilation;
- *across devices* — the leading problem axis is sharded over a mesh
  (``shard_map``): each device owns a contiguous block of
  ``batch_slots / n_devices`` slots and runs the vmapped windowed step
  locally; fleet-wide progress (any slot newly done? how many live?) is
  decided from a ``psum`` of per-slot done masks once per fused dispatch;
  and when a device's live slots drain, whole *problems* migrate from its
  cyclic ring partner — the paper's round-robin redistribution scheme
  (:mod:`repro.core.redistribution`), lifted from regions to problems.

Because batch slots evolve independently (a problem's trajectory never
depends on which slot or device hosts it), sharding and migration preserve
bit-identical results: every terminal ``QuadResult`` — converged, max_iters,
or evicted — matches the single-device service exactly.

Window discipline: each window must be a single static shape per dispatch,
so each device picks the smallest ladder rung covering the widest live slot
it owns (``lax.switch`` at the top level, each branch the vmapped op at one
rung).  By the active-window invariance argument (any window >= n_active is
exact for eval/reductions, any window >= min(2 * n_active, capacity) for the
sort-based advance) every slot gets bit-identical estimates and trajectories
to its own serial run at that rung — there is exactly one compiled
executable per (d, rule, window-rung), shared across the whole batch.  The
advance stage (classify + split/compact) and the global-estimate reductions
are windowed the same way when ``cfg.advance_window`` is on, so the whole
vmapped iteration scales with the widest live population, not store
capacity.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import region_store
from repro.core.adaptive import (
    advance_ladder,
    advance_target,
    donate_argnums,
    eval_ladder,
    make_advance_step,
    make_eval_step,
    result_status,
)
from repro.core.classify import nonfinite_mask
from repro.core.config import QuadratureConfig
from repro.core.distributed import _shard_map
from repro.core.integrands import ParamIntegrand, get_param
from repro.core.redistribution import (
    dispatch_cyclic,
    exchange_pair_stats,
    make_schedule,
    ring_perms,
)
from repro.core.region_store import RegionState
from repro.core.rules import make_rule
from repro.telemetry import NULL

AXIS = "dev"


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "regions",
        "theta",
        "rel_tol",
        "abs_tol",
        "occupied",
        "done",
        "overflow_it",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class BatchState:
    """B independent problems in lockstep: stacked stores + per-slot masks."""

    regions: RegionState  # every leaf has a leading (B,) axis
    theta: Any  # family theta pytree, leaves (B, d)
    rel_tol: jnp.ndarray  # (B,) per-request tolerances
    abs_tol: jnp.ndarray  # (B,)
    occupied: jnp.ndarray  # (B,) bool — slot holds an admitted problem
    done: jnp.ndarray  # (B,) bool — result ready, frozen until released
    overflow_it: jnp.ndarray  # (B,) int32 — it at first overflow, -1 = never

    @property
    def n_slots(self) -> int:
        return self.occupied.shape[0]


def _select_slots(mask: jnp.ndarray, new, old):
    """Per-slot select over a stacked pytree (mask broadcast over trailing dims)."""

    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


def _ppermute_tree(tree, axis_name: str, perm):
    """ppermute every leaf of a pytree (bools ride as uint8 for portability)."""

    def pp(leaf):
        if leaf.dtype == jnp.bool_:
            sent = jax.lax.ppermute(leaf.astype(jnp.uint8), axis_name, perm)
            return sent.astype(bool)
        return jax.lax.ppermute(leaf, axis_name, perm)

    return jax.tree.map(pp, tree)


def estimate_state_bytes(
    cfg: QuadratureConfig, family: Union[ParamIntegrand, str, None] = None
) -> int:
    """Device bytes of the engine's :class:`BatchState` for ``cfg``.

    The stacked store is the dominant service allocation
    (``batch_slots x capacity`` regions); CLIs use this to fail fast on
    slot counts the store memory cannot accommodate, before the engine
    tries (and fails, unhelpfully) to allocate them.
    """
    cfg = cfg.validate()
    if family is None:
        family = cfg.integrand.partition(":")[0]
    if isinstance(family, str):
        family = get_param(family)
    item = jnp.dtype(cfg.dtype).itemsize
    C, d = cfg.capacity, cfg.d
    per_slot = (
        2 * C * d * item  # centers + halfw
        + 2 * C * item  # est + err
        + 4 * C  # axis (int32)
        + 2 * C  # active + fresh (bool)
        + 3 * item + 4 + 1  # fin_integral, fin_error, n_evals, it, overflowed
        + len(family.theta_fields) * d * item  # theta
        + 2 * item + 4 + 2  # rel_tol, abs_tol, overflow_it, occupied, done
    )
    return cfg.batch_slots * per_slot


class BatchEngine:
    """Compiled-step executor for a fixed-shape fleet of one integrand family.

    All problems share ``cfg``'s static shape (d, capacity, rule, domain) and
    differ only in theta and tolerances — that is what makes the batch a
    single XLA program.  The scheduler (:mod:`repro.service.scheduler`)
    drives :meth:`run` from the host, admitting and collecting per slot.

    ``mesh`` / ``devices`` shard the slot axis: slot ``s`` lives on device
    ``s // (batch_slots / n_devices)``.  With one device (the default) the
    engine is the plain single-device vmapped fleet.  ``cfg.service_devices``
    picks a mesh size when neither argument is given (0 = all visible).
    """

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        mesh=None,
        devices=None,
        recorder=NULL,
    ):
        cfg = cfg.validate()
        if family is None:
            family = cfg.integrand.partition(":")[0]
        if isinstance(family, str):
            family = get_param(family)
        self.cfg = cfg
        self.family = family
        self.recorder = recorder
        self.n_slots = cfg.batch_slots

        mesh = self._resolve_mesh(cfg, mesh, devices)
        self.mesh = mesh
        self.n_devices = 1 if mesh is None else mesh.shape[AXIS]
        if self.n_slots % self.n_devices:
            raise ValueError(
                f"batch_slots={self.n_slots} must be a multiple of the mesh "
                f"size ({self.n_devices} devices): each device owns a "
                "contiguous block of batch_slots / n_devices slots"
            )
        self.slots_per_device = self.n_slots // self.n_devices
        # a pair can never usefully exchange more problems than one side owns
        self.rebalance_cap = min(cfg.rebalance_cap, self.slots_per_device)

        lo = np.asarray(cfg.lo(), np.float64)
        hi = np.asarray(cfg.hi(), np.float64)
        self._total_volume = float(np.prod(hi - lo))
        self._width = hi - lo
        self._dtype = jnp.dtype(cfg.dtype)
        # fresh single-slot state spliced into a slot on admit
        self._fresh_slot = region_store.init_state(
            cfg.capacity, lo, hi, cfg.resolved_n_init(), self._dtype
        )
        # theta template fixes the pytree structure + leaf shapes of the fleet
        self.theta_template = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.float64),
            family.sample_theta(cfg.d, np.random.default_rng(0)),
        )

        platform = (
            mesh.devices.flat[0].platform if mesh is not None else None
        )
        donate = donate_argnums(platform)
        # build span only: the jits trace/compile lazily on first dispatch,
        # which the scheduler's "service.compile" span captures instead
        with recorder.span(
            "engine.build",
            backend=self.backend,
            slots=self.n_slots,
            devices=self.n_devices,
        ):
            self._iter = self._make_iter()
            self._step = jax.jit(self._make_step(), donate_argnums=donate)
            self._run = jax.jit(self._make_run(), donate_argnums=donate)
            self._admit = jax.jit(
                self._sharded(self._make_admit()), donate_argnums=donate
            )
            self._release = jax.jit(
                self._sharded(self._make_release()), donate_argnums=donate
            )

    @staticmethod
    def _resolve_mesh(cfg: QuadratureConfig, mesh, devices):
        if mesh is not None:
            if AXIS not in mesh.shape:
                raise ValueError(f"mesh must have a {AXIS!r} axis, got {mesh}")
        else:
            if devices is None:
                if cfg.service_devices == 1:
                    return None
                avail = jax.devices()
                want = (
                    len(avail)
                    if cfg.service_devices == 0
                    else cfg.service_devices
                )
                if want > len(avail):
                    raise ValueError(
                        f"service_devices={cfg.service_devices} but only "
                        f"{len(avail)} devices are visible"
                    )
                devices = avail[:want]
            if len(devices) == 1:
                return None
            mesh = jax.make_mesh((len(devices),), (AXIS,), devices=devices)
        if mesh.shape[AXIS] == 1:
            return None  # a 1-mesh is just the single-device path
        return mesh

    def _sharded(self, fn):
        """Wrap a (state, *scalars) -> state op in shard_map when meshed.

        The state rides split over the slot axis; every other argument is
        replicated.  On a single device the op is used as-is.
        """
        if self.mesh is None:
            return fn

        def wrapper(state, *args):
            return _shard_map(
                fn,
                mesh=self.mesh,
                in_specs=(P(AXIS),) + (P(),) * len(args),
                out_specs=P(AXIS),
            )(state, *args)

        return wrapper

    # --- state construction --------------------------------------------------

    def init(self) -> BatchState:
        """All slots empty; admit problems before stepping."""
        cfg = self.cfg
        B = self.n_slots
        state = BatchState(
            regions=region_store.stacked_empty_state(
                B, cfg.capacity, cfg.d, self._dtype
            ),
            theta=jax.tree.map(
                lambda x: jnp.zeros((B,) + x.shape, self._dtype),
                self.theta_template,
            ),
            rel_tol=jnp.full((B,), cfg.rel_tol, self._dtype),
            abs_tol=jnp.full((B,), cfg.abs_tol, self._dtype),
            occupied=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool),
            overflow_it=jnp.full((B,), -1, jnp.int32),
        )
        if self.mesh is not None:
            state = jax.device_put(state, NamedSharding(self.mesh, P(AXIS)))
        return state

    def place(self, state):
        """Re-place a full logical fleet state onto *this* engine's mesh.

        Every :class:`BatchState` leaf carries the slot axis leading, so one
        sharding re-slices the whole pytree.  This is the elastic half of the
        checkpoint contract (DESIGN.md §6) exposed directly: a state captured
        on any mesh (host arrays included) becomes valid input for this
        engine's fused dispatch — the scheduler's device-loss shrink/regrow
        rebuilds the engine on the surviving sub-mesh and pushes the
        evacuated state through here.
        """
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, state)
        sharding = NamedSharding(self.mesh, P(AXIS))
        return jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), sharding), state)

    # --- jitted slot operations ----------------------------------------------

    def _localize(self, slot):
        """Global slot index -> per-device local index (OOB on non-owners).

        Inside shard_map every device sees its own contiguous slot block;
        the owner writes at ``slot - base`` and everyone else scatters to the
        out-of-bounds sentinel, dropped by ``mode="drop"``.
        """
        if self.n_devices == 1:
            return slot
        local = self.slots_per_device
        base = jax.lax.axis_index(AXIS) * local
        owns = (slot >= base) & (slot < base + local)
        return jnp.where(owns, slot - base, local)

    def _make_admit(self):
        fresh = self._fresh_slot

        def admit(state: BatchState, slot, theta, rel_tol, abs_tol) -> BatchState:
            at = self._localize(slot)
            put = lambda dst, src: dst.at[at].set(src, mode="drop")
            return dataclasses.replace(
                state,
                regions=region_store.write_slot(state.regions, at, fresh, mode="drop"),
                theta=jax.tree.map(put, state.theta, theta),
                rel_tol=put(state.rel_tol, rel_tol),
                abs_tol=put(state.abs_tol, abs_tol),
                occupied=put(state.occupied, True),
                done=put(state.done, False),
                overflow_it=put(state.overflow_it, -1),
            )

        return admit

    def _make_release(self):
        def release(state: BatchState, slot) -> BatchState:
            at = self._localize(slot)
            return dataclasses.replace(
                state,
                occupied=state.occupied.at[at].set(False, mode="drop"),
                done=state.done.at[at].set(False, mode="drop"),
            )

        return release

    def admit(
        self,
        state: BatchState,
        slot: int,
        theta,
        rel_tol: Optional[float] = None,
        abs_tol: Optional[float] = None,
    ) -> BatchState:
        """Write a fresh initial partition + theta into ``slot`` (mid-flight safe)."""
        self._check_slot(slot)
        got = jax.tree.map(lambda x: np.shape(x), theta)
        want = jax.tree.map(lambda x: np.shape(x), self.theta_template)
        if got != want:
            raise ValueError(
                f"theta shape mismatch for family {self.family.name!r}: "
                f"got {got}, want {want}"
            )
        return self._admit(
            state,
            jnp.asarray(slot, jnp.int32),
            jax.tree.map(lambda x: jnp.asarray(x, self._dtype), theta),
            jnp.asarray(self.cfg.rel_tol if rel_tol is None else rel_tol, self._dtype),
            jnp.asarray(self.cfg.abs_tol if abs_tol is None else abs_tol, self._dtype),
        )

    def release(self, state: BatchState, slot: int) -> BatchState:
        """Free a collected slot (its store stays stale until the next admit)."""
        self._check_slot(slot)
        return self._release(state, jnp.asarray(slot, jnp.int32))

    def _check_slot(self, slot: int) -> None:
        # JAX drops out-of-bounds scatter updates, so a bad index would
        # otherwise no-op silently and strand the request.
        if not 0 <= int(slot) < self.n_slots:
            raise IndexError(f"slot {slot} out of range [0, {self.n_slots})")

    # --- the batched adaptive step -------------------------------------------

    def _make_iter(self):
        """One adaptive iteration over whatever slot block the caller holds.

        Shape-polymorphic in the leading slot axis: the single-device step
        applies it to all ``batch_slots`` slots, the sharded fused run to each
        device's local block — the same traced math either way, which is what
        makes device-count parity structural rather than coincidental.
        Returns ``(state, metrics, n_new_done)``.
        """
        cfg = self.cfg
        family = self.family
        total_volume = self._total_volume
        C = cfg.capacity
        ladder = eval_ladder(cfg)
        rungs = jnp.asarray(ladder, jnp.int32)
        adv_ladder = advance_ladder(cfg)
        adv_rungs = jnp.asarray(adv_ladder, jnp.int32)

        def eval_branch(window: int):
            def eval_one(regions: RegionState, theta) -> RegionState:
                # theta rides as a rule operand (not a closure) so the Pallas
                # kernel path works under vmap — see rules.make_rule
                rule = make_rule(cfg, family.fn, theta=theta)
                return make_eval_step(cfg, rule, window=window)(regions)

            return jax.vmap(eval_one)

        branches = [eval_branch(w) for w in ladder]

        # One windowed branch per advance rung, carrying the whole
        # post-eval tail of the iteration: the global-estimate reductions,
        # the per-slot budget, and the serial drivers' advance (vmapped with
        # per-slot traced tolerances).  The rung covers
        # min(2 * n_active, C) for the widest live slot — any wider window
        # is bit-identical for the narrower slots, so one shared rung is
        # exact, and folding the reductions into the same switch keeps the
        # traced program (and its compile time) proportional to the ladder.
        def tail_branch(window: int):
            adv = jax.vmap(
                make_advance_step(cfg, total_volume, self._width, window=window)
            )

            def est_one(regions: RegionState):
                integral, error = regions.global_estimates(window=window)
                n = jnp.sum(regions.active[:window]).astype(jnp.int32)
                return integral, error, n

            def fn(regions: RegionState, abs_tol, rel_tol):
                integral, error, n_active = jax.vmap(est_one)(regions)
                budget = jnp.maximum(abs_tol, jnp.abs(integral) * rel_tol)
                advanced = adv(regions, budget, rel_tol)
                return integral, error, n_active, budget, advanced

            return fn

        tail_branches = [tail_branch(w) for w in adv_ladder]

        def iter_fn(state: BatchState):
            live = state.occupied & ~state.done
            counts = jnp.sum(state.regions.active, axis=1).astype(jnp.int32)
            widest = jnp.max(jnp.where(live, counts, 0))
            ix = region_store.rung_index(rungs, widest)

            evald = jax.lax.switch(ix, branches, state.regions, state.theta)
            regions = _select_slots(live, evald, state.regions)

            # --- non-finite quarantine ---------------------------------------
            # A NaN/Inf region estimate (pathological theta, corrupted slot)
            # must be contained to its own slot BEFORE the global-estimate
            # reductions run, or it poisons the slot's budget check forever
            # and — worse — every psum'd fleet metric downstream.  Zero the
            # offending regions' contributions, deactivate them, and flag the
            # slot terminal with status "nonfinite".  For healthy slots the
            # masks are all-False and every where() is a bitwise identity, so
            # serial parity is untouched.
            bad = nonfinite_mask(regions.est, regions.err, regions.active)
            bad = bad & live[:, None]
            # the finalised accumulators are equally load-bearing: once one
            # goes non-finite (corrupted state — nothing healthy writes NaN
            # there) the slot's global estimate can never recover, so flag
            # the slot and zero the accumulator out of the reductions
            bad_fin = live & ~(
                jnp.isfinite(regions.fin_integral)
                & jnp.isfinite(regions.fin_error)
            )
            nonfinite = jnp.any(bad, axis=1) | bad_fin
            regions = dataclasses.replace(
                regions,
                est=jnp.where(bad, 0.0, regions.est),
                err=jnp.where(bad, 0.0, regions.err),
                active=regions.active & ~bad,
                fin_integral=jnp.where(bad_fin, 0.0, regions.fin_integral),
                fin_error=jnp.where(bad_fin, 0.0, regions.fin_error),
            )

            if len(adv_ladder) > 1:
                ixa = region_store.rung_index(adv_rungs, advance_target(widest, C))
                integral, error, n_active, budget, advanced = jax.lax.switch(
                    ixa, tail_branches, regions, state.abs_tol, state.rel_tol
                )
            else:
                integral, error, n_active, budget, advanced = tail_branches[0](
                    regions, state.abs_tol, state.rel_tol
                )
            converged = error <= budget
            # Capacity pressure is not instantly terminal: the serial driver
            # grinds past overflow and often converges, so an overflowed slot
            # keeps refining for ``evict_patience`` further iterations (exact
            # serial parity for transient saturation) before being evicted.
            overflow_it = jnp.where(
                regions.overflowed & (state.overflow_it < 0),
                regions.it,
                state.overflow_it,
            )
            evicted = regions.overflowed & (
                regions.it - overflow_it >= cfg.evict_patience
            )
            # The serial driver runs exactly max_iters eval sweeps: post-eval
            # ``it == max_iters - 1`` means this sweep was the last one, so
            # the slot freezes NOW — checking ``it >= max_iters`` instead
            # would eval the final advance's children one extra time and
            # break bit-parity with `integrate` on the max_iters path.
            capped = regions.it >= cfg.max_iters - 1
            terminal = converged | (n_active == 0) | capped | evicted | nonfinite
            done = state.done | (live & terminal)
            n_new_done = jnp.sum(done & ~state.done).astype(jnp.int32)

            regions = _select_slots(state.occupied & ~done, advanced, regions)
            # Serial parity on the counter too: after capturing its final
            # metrics the serial driver still runs (and counts) one advance
            # before the loop exhausts.  The frozen slot skips the splitting
            # (its estimates must stay collectable) but mirrors the counter.
            bump = live & capped & ~converged & (n_active > 0)
            regions = dataclasses.replace(
                regions, it=regions.it + bump.astype(regions.it.dtype)
            )

            metrics = {
                "integral": integral,
                "error": error,
                "n_active": n_active,
                "it": regions.it,
                "n_evals": regions.n_evals,
                "overflowed": regions.overflowed,
                "converged": converged,
                "nonfinite": nonfinite,
                "done": done,
                "occupied": state.occupied,
                "window": rungs[ix],
            }
            return (
                dataclasses.replace(
                    state, regions=regions, done=done, overflow_it=overflow_it
                ),
                metrics,
                n_new_done,
            )

        return iter_fn

    def _make_step(self):
        iter_fn = self._iter

        def step(state: BatchState):
            state, metrics, _ = iter_fn(state)
            return state, metrics

        return step

    def step(self, state: BatchState):
        """One fused iteration for every live slot; returns (state, metrics).

        ``metrics`` holds per-slot device arrays: ``integral``, ``error``,
        ``n_active``, ``it``, ``n_evals``, ``overflowed``, ``converged``,
        ``done``, ``occupied`` plus the scalar eval ``window`` used.  Slots
        whose ``done`` flips on are frozen (no further advance) until the
        scheduler collects and releases them.  (On a sharded engine this is
        the GSPMD form; the scheduler drives :meth:`run` instead.)
        """
        return self._step(state)

    # --- problem-level cyclic rebalancing ------------------------------------

    def _make_rebalance_round(self):
        """One migration round: the paper's cyclic round-robin pairing
        (:func:`repro.core.redistribution.redistribute`), lifted from regions
        to whole problems.  A device whose live-slot count fell below the
        fleet's fair share — its problems converged and were collected while
        the queue ran dry — receives up to ``rebalance_cap`` entire problems
        (region store + theta + tolerances) from its ring partner at the
        scheduled shift.  Migration cannot change any result: slots evolve
        independently, so moving one only changes which device pays for it.
        """
        n_dev = self.n_devices
        cap = self.rebalance_cap
        local = self.slots_per_device
        schedule = make_schedule(n_dev)

        def round_fn(shift: int):
            _, perm_up = ring_perms(n_dev, shift)

            def fn(state: BatchState):
                occupied = state.occupied
                live = occupied & ~state.done
                n_live = jnp.sum(live).astype(jnp.int32)
                n_free = jnp.sum(~occupied).astype(jnp.int32)
                total = jax.lax.psum(n_live, AXIS)
                fair = total // n_dev  # floor: migrate only into real holes
                surplus = jnp.maximum(n_live - fair, 0)
                deficit = jnp.maximum(fair - n_live, 0)
                stats = jnp.stack([n_live, n_free, surplus, deficit])
                down_stats, up_stats = exchange_pair_stats(
                    stats, AXIS, n_dev, shift
                )
                _, down_free, _, down_deficit = down_stats
                _, _, up_surplus, _ = up_stats
                n_send = jnp.minimum(
                    jnp.minimum(jnp.int32(cap), surplus),
                    jnp.minimum(down_deficit, down_free),
                )
                n_recv = jnp.minimum(
                    jnp.minimum(jnp.int32(cap), up_surplus),
                    jnp.minimum(deficit, n_free),
                )

                idx = jnp.arange(local, dtype=jnp.int32)
                j = jnp.arange(cap, dtype=jnp.int32)
                base = (jax.lax.axis_index(AXIS) * local).astype(jnp.int32)

                # --- donor: pick the highest-index live slots --------------
                skey = jnp.where(live, -idx, jnp.int32(local + 1))
                src_local = jnp.argsort(skey)[:cap].astype(jnp.int32)
                valid_send = j < n_send
                payload = (
                    state.regions,
                    state.theta,
                    state.rel_tol,
                    state.abs_tol,
                    state.overflow_it,
                )
                picked = jax.tree.map(lambda leaf: leaf[src_local], payload)
                src_global = jnp.where(valid_send, base + src_local, -1)
                incoming = _ppermute_tree(picked, AXIS, perm_up)
                src_global_in = jax.lax.ppermute(src_global, AXIS, perm_up)
                send_mask = jnp.zeros((local,), bool).at[src_local].set(valid_send)
                occupied = occupied & ~send_mask

                # --- receiver: splice into the lowest-index free slots -----
                rkey = jnp.where(state.occupied, jnp.int32(local + 1), idx)
                dst_local = jnp.argsort(rkey)[:cap].astype(jnp.int32)
                valid_recv = j < n_recv
                dst = jnp.where(valid_recv, dst_local, local)  # local = dropped
                in_regions, in_theta, in_rel, in_abs, in_overflow = incoming
                put = lambda cur, new: cur.at[dst].set(new, mode="drop")
                moved = jnp.stack(
                    [
                        jnp.where(valid_recv, src_global_in, -1),
                        jnp.where(valid_recv, base + dst_local, -1),
                    ],
                    axis=1,
                )
                return (
                    dataclasses.replace(
                        state,
                        regions=jax.tree.map(put, state.regions, in_regions),
                        theta=jax.tree.map(put, state.theta, in_theta),
                        rel_tol=put(state.rel_tol, in_rel),
                        abs_tol=put(state.abs_tol, in_abs),
                        overflow_it=put(state.overflow_it, in_overflow),
                        occupied=occupied.at[dst].set(True, mode="drop"),
                        done=put(state.done, jnp.zeros((cap,), bool)),
                    ),
                    moved,
                )

            return fn

        def rebalance(state: BatchState, t):
            return dispatch_cyclic(schedule, t, round_fn, state)

        return rebalance

    # --- the fused multi-iteration dispatch -----------------------------------

    def _make_run(self):
        """Build the K-fused dispatch (K = ``cfg.sync_every``).

        Runs up to ``max_steps`` iterations in one XLA dispatch and stops
        early — remaining iterations become pass-throughs — as soon as any
        live slot's ``done`` flips on (decided from a psum of per-slot done
        masks, the fleet's single global sync point), so the host scheduler
        observes every collection at its exact iteration and can replay
        admission/eviction decisions identically to an unfused loop.
        """
        cfg = self.cfg
        n_dev = self.n_devices
        iter_fn = self._iter
        rebalance_on = n_dev > 1 and cfg.rebalance != "off"
        rebalance = self._make_rebalance_round() if rebalance_on else None
        moved_rows = self.rebalance_cap if n_dev > 1 else 0
        dtype = self._dtype

        def no_moves():
            return jnp.full((moved_rows, 2), -1, jnp.int32)

        def zero_metrics(state: BatchState):
            B = state.occupied.shape[0]
            z = jnp.zeros
            return {
                "integral": z((B,), dtype),
                "error": z((B,), dtype),
                "n_active": z((B,), jnp.int32),
                "it": z((B,), jnp.int32),
                "n_evals": z((B,), dtype),
                "overflowed": z((B,), bool),
                "converged": z((B,), bool),
                "nonfinite": z((B,), bool),
                "done": z((B,), bool),
                "occupied": z((B,), bool),
                "window": z((), jnp.int32),
            }

        def run_body(state: BatchState, max_steps, tick):
            def one(carry, t):
                state, stop = carry
                go = (~stop) & (t < max_steps)

                def do(state):
                    state, metrics, n_new = iter_fn(state)
                    if n_dev > 1:
                        n_new = jax.lax.psum(n_new, AXIS)
                    if rebalance_on:
                        state, moved = rebalance(state, tick + t)
                    else:
                        moved = no_moves()
                    return state, metrics, moved, n_new > 0

                def skip(state):
                    return state, zero_metrics(state), no_moves(), jnp.asarray(True)

                state, m, moved, stop = jax.lax.cond(go, do, skip, state)
                return (state, stop), (m, moved, go)

            (state, _), (ms, moved, executed) = jax.lax.scan(
                one,
                (state, jnp.asarray(False)),
                jnp.arange(cfg.sync_every, dtype=jnp.int32),
            )
            # per-device eval window, shaped for the slot-axis out_spec
            ms = {**ms, "window": ms["window"][:, None]}
            return state, ms, executed, moved

        if self.mesh is None:
            return run_body
        return _shard_map(
            run_body,
            mesh=self.mesh,
            in_specs=(P(AXIS), P(), P()),
            out_specs=(P(AXIS), P(None, AXIS), P(), P(None, AXIS, None)),
        )

    backend = "cubature"

    def status_of(
        self,
        converged: bool,
        n_active: int,
        it: int,
        overflowed: bool,
        nonfinite: bool = False,
    ) -> str:
        """Terminal taxonomy for collected slots (scheduler hook; the MC
        engine pool supplies its own — MC has no region store, so no
        capacity/no_active statuses)."""
        return result_status(
            converged, n_active, it, self.cfg, overflowed, nonfinite
        )

    def run(self, state: BatchState, max_steps: int, tick: int):
        """Up to ``min(max_steps, cfg.sync_every)`` fused iterations.

        Returns ``(state, metrics, executed, moved)``:

        - ``metrics`` — per-slot arrays stacked over the fused iterations,
          shape ``(sync_every, batch_slots)`` (``window`` is per device);
        - ``executed`` — ``(sync_every,)`` prefix mask of iterations that
          actually ran; the first unexecuted row follows either the
          ``max_steps`` cap or an early exit on a done-flip, so the last
          executed row is where every newly finished slot finished;
        - ``moved`` — ``(sync_every, n_devices * rebalance_cap, 2)`` int32
          ``(src_slot, dst_slot)`` migration records per iteration (-1 =
          unused row); the host applies them to its slot -> request map in
          iteration order, after collecting that iteration's done slots.

        ``tick`` is the fleet-global iteration number of the first fused
        iteration (indexes the cyclic migration schedule).
        """
        return self._run(
            state,
            jnp.asarray(min(int(max_steps), self.cfg.sync_every), jnp.int32),
            jnp.asarray(tick, jnp.int32),
        )
