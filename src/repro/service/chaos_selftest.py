"""Self-contained graceful-degradation chaos self-test (subprocess-run).

Must be launched as ``python -m repro.service.chaos_selftest [n_devices]`` —
sets XLA_FLAGS before importing jax, then runs the batch quadrature service
through every fault injector in :mod:`repro.service.faults` on meshes of
1, 2, ..., n_devices virtual devices and asserts the graceful-degradation
contract:

- **survival**: the service completes every scenario (no hang, no unhandled
  error), and every request yields exactly one final result;
- **containment**: in a fleet with NaN-poisoned / corrupted slots, every
  *healthy* request converges and its ``(integral, error, status,
  iterations, n_evals)`` is bit-identical to the fault-free run — a faulty
  slot is quarantined without perturbing anyone else's trajectory;
- **re-routing**: quarantined/corrupted requests carry attempt provenance
  (``attempts=2``, ``retried_from``, fallback ``backend``);
- **resume parity**: after a mid-serve crash, ``resume=True`` replays to a
  result set whose union with the pre-crash yields is exactly the fault-free
  run's, bit-for-bit (duplicates from replayed post-snapshot work included);
- **deadlines**: an expired SLO evicts with a best-effort partial result
  instead of hanging the slot;
- **device loss** (meshes of >= 2): a device killed mid-run is evacuated and
  the fleet completes on the shrunken mesh — unaffected requests bit-identical
  to the fault-free run, affected requests terminating with snapshot-recovery
  or re-admission provenance, the shrunken ring satisfying the
  ``make_schedule``/``ring_perms`` invariants; transient faults retry to a
  *fully* bit-identical run; a healed device regrows the mesh;
- **elastic restore**: a snapshot saved on the widest mesh restores onto
  every smaller device count with all slots bit-identical (DESIGN.md §6).

Human progress goes through ``logging`` (``-q``/``-v``); the machine-readable
``RESULT_JSON:`` line on stdout stays byte-identical for CI consumers.
Prints one JSON blob on the last line.
"""

import argparse
import dataclasses
import json
import os
import tempfile

from repro.telemetry.logutil import add_verbosity_flags, setup_logging


def _full(results):
    """Full result tuples: scheduling included (cross-device-count parity)."""
    return [
        (
            r.req_id,
            float(r.integral).hex(),
            float(r.error).hex(),
            r.status,
            r.iterations,
            r.n_evals,
            r.admitted_at,
            r.finished_at,
        )
        for r in sorted(results, key=lambda r: r.req_id)
    ]


def _values(results):
    """Value tuples: scheduling excluded.  A slot's numeric trajectory is a
    pure function of (theta, tolerances, cfg) — independent of *when* it was
    admitted and of every other slot — so these are the right unit for
    comparing healthy requests between a faulty fleet (where extra/failed
    requests shift admission order) and the fault-free fleet."""
    return {
        r.req_id: (
            float(r.integral).hex(),
            float(r.error).hex(),
            r.status,
            r.iterations,
            r.n_evals,
        )
        for r in results
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("n_devices", nargs="?", type=int, default=4)
    add_verbosity_flags(ap)
    args = ap.parse_args()
    log = setup_logging(quiet=args.quiet, verbose=args.verbose)
    n_dev = args.n_devices
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import QuadratureConfig
    from repro.core.integrands import get_param
    from repro.core.redistribution import check_ring_invariants
    from repro.service import BatchScheduler, QuadRequest
    from repro.service.checkpoint import ServiceCheckpointer
    from repro.service.faults import (
        DeviceDown,
        SimulatedCrash,
        corrupt_slot_hook,
        crash_at,
        nan_family,
        poison_theta,
        storm_requests,
    )
    from repro.service.routing import GracefulScheduler

    assert len(jax.devices()) == n_dev, jax.devices()
    counts = [c for c in (1, 2, 4) if c <= n_dev]
    family = get_param("genz_gaussian")
    d = 2
    cfg = QuadratureConfig(
        d=d,
        integrand="genz_gaussian",
        rel_tol=1e-3,
        capacity=1 << 10,
        batch_slots=8,
        max_iters=80,
        sync_every=4,
    )

    def requests(n, seed=0, rel_tols=None):
        rng = np.random.default_rng(seed)
        return [
            QuadRequest(
                req_id=i,
                theta=family.sample_theta(d, rng),
                rel_tol=None if rel_tols is None else rel_tols[i],
            )
            for i in range(n)
        ]

    # req 0 runs at a tight tolerance so it is reliably still in flight when
    # the corruption / deadline injectors fire mid-serve
    rel_tols = [1e-6] + [1e-3] * 9
    base_reqs = requests(10, rel_tols=rel_tols)
    healthy_ids = {r.req_id for r in base_reqs}

    out = {"n_devices": n_dev, "device_counts": counts, "scenarios": {}}
    baseline_by_count = {}
    for c in counts:
        devices = jax.devices()[:c]
        scen = {}
        log.info("devices=%d ...", c)

        # --- fault-free reference -------------------------------------------
        sched = BatchScheduler(cfg, family, devices=devices)
        baseline = list(sched.serve(list(base_reqs)))
        assert all(r.status == "converged" for r in baseline), _full(baseline)
        baseline_by_count[c] = _full(baseline)
        base_vals = _values(baseline)
        scen["baseline"] = {"n_results": len(baseline)}
        log.debug("  baseline: %d results", len(baseline))

        # --- NaN-poisoned integrands ----------------------------------------
        # Three poisoned requests ride along with the ten healthy ones; the
        # wrapped family NaNs for sentinel thetas only.  The cubature pass
        # quarantines them, the graceful layer retries them on VEGAS (which
        # also NaNs — the integrand really is broken), and the final results
        # carry the full provenance.  Healthy requests must be untouched.
        wrapped = nan_family(family)
        poisoned = [
            QuadRequest(req_id=100 + i, theta=poison_theta(base_reqs[0].theta))
            for i in range(3)
        ]
        mixed = base_reqs[:5] + poisoned + base_reqs[5:]
        graceful = GracefulScheduler(cfg, wrapped, devices=devices)
        results = list(graceful.serve(list(mixed)))
        assert len(results) == len(mixed), _full(results)
        vals = _values(results)
        for rid in healthy_ids:
            assert vals[rid] == base_vals[rid], (rid, vals[rid], base_vals[rid])
            assert vals[rid][2] == "converged", vals[rid]
        for p in poisoned:
            r = next(r for r in results if r.req_id == p.req_id)
            assert r.status == "nonfinite", r
            assert r.attempts == 2 and r.retried_from == "nonfinite", r
            assert r.backend == "vegas", r
        assert graceful.last_stats["quarantines"] >= 2 * len(poisoned), (
            graceful.last_stats
        )
        assert graceful.last_stats["reroutes"] == len(poisoned), (
            graceful.last_stats
        )
        log.debug(
            "  nan_injection: %d quarantines, %d reroutes",
            graceful.last_stats["quarantines"],
            graceful.last_stats["reroutes"],
        )
        scen["nan_injection"] = {
            "quarantines": graceful.last_stats["quarantines"],
            "reroutes": graceful.last_stats["reroutes"],
            "healthy_parity": True,
        }

        # --- forced slot corruption -----------------------------------------
        # Slot 0 (holding the tight-tolerance req 0) has its region estimates
        # overwritten with NaN mid-serve.  The engine must quarantine it the
        # next iteration, and the graceful layer re-routes the request to
        # VEGAS — where, the integrand being perfectly healthy, it produces a
        # real estimate again.
        graceful = GracefulScheduler(
            cfg,
            family,
            devices=devices,
            on_tick=corrupt_slot_hook(0, 1, req_id=0),
        )
        results = list(graceful.serve(list(base_reqs)))
        assert len(results) == len(base_reqs), _full(results)
        vals = _values(results)
        corrupted = next(r for r in results if r.req_id == 0)
        assert corrupted.attempts == 2, corrupted
        assert corrupted.retried_from == "nonfinite", corrupted
        assert corrupted.backend == "vegas", corrupted
        assert corrupted.status in ("converged", "max_iters"), corrupted
        assert np.isfinite(corrupted.integral), corrupted
        exact = family.exact(d, base_reqs[0].theta)
        assert abs(corrupted.integral - exact) <= 1e-2 * abs(exact), (
            corrupted.integral,
            exact,
        )
        for rid in healthy_ids - {0}:
            assert vals[rid] == base_vals[rid], (rid, vals[rid], base_vals[rid])
        log.debug("  slot_corruption: rerouted status=%s", corrupted.status)
        scen["slot_corruption"] = {
            "rerouted_status": corrupted.status,
            "healthy_parity": True,
        }

        # --- mid-serve crash + resume ---------------------------------------
        with tempfile.TemporaryDirectory() as tmp:
            ckpt = ServiceCheckpointer(tmp)
            crashing = BatchScheduler(
                cfg,
                family,
                devices=devices,
                checkpointer=ckpt,
                # snapshot every OTHER admission tick and crash off-cycle, so
                # some results land between the last snapshot and the crash:
                # the resumed run must re-serve them bit-identically
                checkpoint_every=2,
                on_tick=crash_at(3),
            )
            pre = []
            try:
                for r in crashing.serve(list(base_reqs)):
                    pre.append(r)
            except SimulatedCrash:
                pass
            else:
                raise AssertionError("crash injector never fired")
            assert ckpt.latest_step() is not None, os.listdir(tmp)
            resumed = BatchScheduler(
                cfg, family, devices=devices, checkpointer=ckpt
            )
            post = list(resumed.serve(list(base_reqs), resume=True))
            by_id = {}
            for r in pre + post:
                t = _full([r])[0]
                # post-snapshot work is replayed: duplicates must be
                # bit-identical, not merely close
                assert by_id.setdefault(r.req_id, t) == t, (by_id[r.req_id], t)
            union = [by_id[k] for k in sorted(by_id)]
            assert union == baseline_by_count[c], (union, baseline_by_count[c])
            replayed = len(pre) + len(post) - len(by_id)
            assert replayed > 0, (len(pre), len(post))
            log.debug(
                "  crash_resume: pre=%d post=%d replayed=%d",
                len(pre),
                len(post),
                replayed,
            )
            scen["crash_resume"] = {
                "pre_crash": len(pre),
                "post_resume": len(post),
                "replayed": replayed,
                "union_parity": True,
            }

        # --- queue storm ----------------------------------------------------
        storm_n = 40
        sched = BatchScheduler(cfg, family, devices=devices)
        results = list(sched.serve(storm_requests(family, d, storm_n, seed=11)))
        assert len(results) == storm_n, len(results)
        assert all(r.status == "converged" for r in results), _full(results)[:3]
        midflight = sum(1 for r in results if r.admitted_at > 0)
        assert midflight > 0, _full(results)
        log.debug("  queue_storm: %d results, %d midflight", len(results), midflight)
        scen["queue_storm"] = {
            "n_results": len(results),
            "midflight_admissions": midflight,
        }

        # --- deadline SLO ---------------------------------------------------
        # Req 0 gets a hopeless tolerance and a small evaluation budget: it
        # must be evicted with a finite best-effort partial, while everyone
        # else's trajectory stays bit-identical to the fault-free run.
        slo_reqs = [
            dataclasses.replace(base_reqs[0], rel_tol=1e-12, max_evals=3e4)
        ] + base_reqs[1:]
        sched = BatchScheduler(cfg, family, devices=devices)
        results = list(sched.serve(slo_reqs))
        assert len(results) == len(slo_reqs), _full(results)
        vals = _values(results)
        dl = next(r for r in results if r.req_id == 0)
        assert dl.status == "deadline", dl
        assert dl.n_evals > 3e4, dl
        assert np.isfinite(dl.integral) and np.isfinite(dl.error), dl
        assert sched.last_stats["deadlines"] == 1, sched.last_stats
        for rid in healthy_ids - {0}:
            assert vals[rid] == base_vals[rid], (rid, vals[rid], base_vals[rid])
        log.debug("  deadline: partial after %d evals", dl.n_evals)
        scen["deadline"] = {"partial_evals": dl.n_evals, "healthy_parity": True}

        # --- device loss (elastic fleet) ------------------------------------
        # The watchdog/evacuation contract only exists on multi-device
        # meshes: a single-device engine has nowhere to evacuate to.
        if c >= 2:
            # permanent loss, no snapshot coverage: the failed device's
            # requests are re-admitted from scratch with provenance; every
            # request (affected included — trajectories are placement-pure)
            # lands value-bit-identical to the fault-free run
            dd = DeviceDown(device=1, at_tick=2)
            sched = BatchScheduler(
                cfg,
                family,
                devices=devices,
                fault_injector=dd,
                max_dispatch_retries=1,
                retry_backoff_s=0.0,
            )
            results = list(sched.serve(list(base_reqs)))
            assert len(results) == len(base_reqs), _full(results)
            vals = _values(results)
            for rid in healthy_ids:
                assert vals[rid] == base_vals[rid], (rid, vals[rid], base_vals[rid])
            affected = [r for r in results if r.evacuated]
            assert affected, _full(results)
            for r in affected:
                assert r.evacuated == "readmit", r
                assert r.attempts == 2 and r.retried_from == "device_lost", r
            st = sched.last_stats
            assert st["dispatch_retries"] == 1, st
            assert st["mesh_shrinks"] == 1, st
            assert st["evacuations"] == len(affected), (st, len(affected))
            assert sched.engine.n_devices < c, sched.engine.n_devices
            check_ring_invariants(sched.engine.n_devices)
            log.debug(
                "  device_kill_readmit: %d evacuated, mesh %d -> %d",
                len(affected),
                c,
                sched.engine.n_devices,
            )
            scen["device_kill_readmit"] = {
                "evacuated": len(affected),
                "shrunk_to": sched.engine.n_devices,
                "healthy_parity": True,
            }

            # permanent loss with snapshot coverage: slots present in the
            # newest snapshot rewind and replay (no extra attempt consumed);
            # slots the snapshot missed fall back to re-admission
            with tempfile.TemporaryDirectory() as tmp:
                ckpt = ServiceCheckpointer(tmp)
                dd = DeviceDown(device=1, at_tick=3)
                sched = BatchScheduler(
                    cfg,
                    family,
                    devices=devices,
                    checkpointer=ckpt,
                    checkpoint_every=1,
                    fault_injector=dd,
                    max_dispatch_retries=1,
                    retry_backoff_s=0.0,
                )
                results = list(sched.serve(list(base_reqs)))
            assert len(results) == len(base_reqs), _full(results)
            vals = _values(results)
            for rid in healthy_ids:
                assert vals[rid] == base_vals[rid], (rid, vals[rid], base_vals[rid])
            affected = [r for r in results if r.evacuated]
            assert any(r.evacuated == "snapshot" for r in affected), _full(results)
            for r in affected:
                assert r.evacuated in ("snapshot", "readmit"), r
                if r.evacuated == "snapshot":
                    assert r.attempts == 1 and r.retried_from is None, r
                else:
                    assert r.attempts == 2 and r.retried_from == "device_lost", r
            st = sched.last_stats
            assert st["mesh_shrinks"] == 1, st
            assert st["evacuations"] == len(affected), (st, len(affected))
            log.debug(
                "  device_kill_snapshot: %d recovered / %d evacuated",
                sum(1 for r in affected if r.evacuated == "snapshot"),
                len(affected),
            )
            scen["device_kill_snapshot"] = {
                "evacuated": len(affected),
                "snapshot_recovered": sum(
                    1 for r in affected if r.evacuated == "snapshot"
                ),
                "healthy_parity": True,
            }

            # transient fault: the watchdog's retry budget covers it, so the
            # run is FULLY bit-identical (scheduling included) — the fault
            # never becomes visible in any result
            dd = DeviceDown(device=1, at_tick=2, transient_failures=2)
            sched = BatchScheduler(
                cfg,
                family,
                devices=devices,
                fault_injector=dd,
                max_dispatch_retries=3,
                retry_backoff_s=0.0,
            )
            results = list(sched.serve(list(base_reqs)))
            assert _full(results) == baseline_by_count[c], _full(results)[:2]
            st = sched.last_stats
            assert st["dispatch_retries"] == 2, st
            assert st["mesh_shrinks"] == 0 and st["evacuations"] == 0, st
            assert sched.engine.n_devices == c, sched.engine.n_devices
            log.debug("  device_transient: full parity after 2 retries")
            scen["device_transient"] = {"retries": 2, "full_parity": True}

            # loss followed by heal: the mesh shrinks, serves, and regrows
            # back to the original device count at a later admission tick
            storm_n2 = 24
            ref = list(
                BatchScheduler(cfg, family, devices=devices).serve(
                    storm_requests(family, d, storm_n2, seed=7)
                )
            )
            dd = DeviceDown(device=1, at_tick=2, restore_at_tick=6)
            sched = BatchScheduler(
                cfg,
                family,
                devices=devices,
                fault_injector=dd,
                max_dispatch_retries=1,
                retry_backoff_s=0.0,
            )
            results = list(sched.serve(storm_requests(family, d, storm_n2, seed=7)))
            assert len(results) == storm_n2, len(results)
            assert _values(results) == _values(ref), _full(results)[:2]
            st = sched.last_stats
            assert st["mesh_shrinks"] == 1, st
            assert st["mesh_regrows"] >= 1, st
            assert sched.engine.n_devices == c, sched.engine.n_devices
            check_ring_invariants(sched.engine.n_devices)
            log.debug(
                "  device_regrow: shrink + %d regrows back to %d devices",
                st["mesh_regrows"],
                sched.engine.n_devices,
            )
            scen["device_regrow"] = {
                "regrows": st["mesh_regrows"],
                "final_devices": sched.engine.n_devices,
                "healthy_parity": True,
            }

        out["scenarios"][f"devices_{c}"] = scen

    # the fault-free reference itself must hold the cross-device-count
    # parity invariant (full tuples, scheduling included)
    ref = baseline_by_count[counts[0]]
    for c in counts[1:]:
        assert baseline_by_count[c] == ref, (c, baseline_by_count[c][:2], ref[:2])

    # --- elastic restore across mesh sizes (DESIGN.md §6) -------------------
    # One crash on the widest mesh, then resume the same snapshot set onto
    # every *smaller* device count: the manager loads full logical arrays and
    # re-shards, so each resumed fleet must replay to the identical result
    # set — the direct test of the restore-across-mesh-sizes claim.
    c_hi = counts[-1]
    if c_hi > 1:
        restored_to = {}
        with tempfile.TemporaryDirectory() as tmp:
            ckpt = ServiceCheckpointer(tmp)
            crashing = BatchScheduler(
                cfg,
                family,
                devices=jax.devices()[:c_hi],
                checkpointer=ckpt,
                checkpoint_every=2,
                on_tick=crash_at(3),
            )
            pre = []
            try:
                for r in crashing.serve(list(base_reqs)):
                    pre.append(r)
            except SimulatedCrash:
                pass
            else:
                raise AssertionError("crash injector never fired")
            for c_lo in [c for c in counts if c < c_hi]:
                # restore-only (checkpoint_every=0): the snapshot set stays
                # pristine, so every c_lo resumes from the same crash point
                resumed = BatchScheduler(
                    cfg, family, devices=jax.devices()[:c_lo], checkpointer=ckpt
                )
                post = list(resumed.serve(list(base_reqs), resume=True))
                by_id = {}
                for r in pre + post:
                    t = _full([r])[0]
                    assert by_id.setdefault(r.req_id, t) == t, (
                        c_lo,
                        by_id[r.req_id],
                        t,
                    )
                union = [by_id[k] for k in sorted(by_id)]
                assert union == baseline_by_count[c_hi], (c_lo, union[:2])
                restored_to[str(c_lo)] = len(post)
                log.debug(
                    "  elastic_restore: %d -> %d devices, %d post-resume results",
                    c_hi,
                    c_lo,
                    len(post),
                )
        out["elastic_restore"] = {
            "from_devices": c_hi,
            "restored_to": restored_to,
            "union_parity": True,
        }

    print("RESULT_JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
