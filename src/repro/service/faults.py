"""Deterministic fault injectors for the quadrature service.

Chaos testing only earns its keep if a failure reproduces: every injector
here is a pure function of its explicit inputs (a seed, a slot index, an
iteration threshold) — no wall clock, no global state — so a chaos run that
trips an assertion replays bit-for-bit.

Injector families (used by :mod:`repro.service.chaos_selftest`):

- **NaN integrands** — :func:`nan_family` wraps an integrand family so that
  thetas carrying the :data:`NAN_SENTINEL` evaluate to NaN everywhere, and
  :func:`poison_theta` plants the sentinel.  The wrapper stays traceable and
  vmappable, and for unpoisoned thetas it computes ``where(False, nan, f)``
  — a bitwise identity — so healthy requests are unaffected by the wrapping
  itself.
- **slot corruption** — :func:`corrupt_slot` overwrites one slot's on-device
  state with non-finite values (simulating a soft memory error / bad
  kernel), exercising the engines' quarantine paths.
- **crash points** — :func:`crash_at` raises :class:`SimulatedCrash` from the
  scheduler's ``on_tick`` hook at a chosen iteration, exercising
  checkpoint/resume.
- **queue storms** — :func:`storm_requests` builds a deterministic burst of
  requests far exceeding the fleet's slot count, exercising admission
  backpressure.
- **device loss** — :class:`DeviceDown` makes one device of the mesh fail at
  a chosen iteration (raising :class:`~repro.service.scheduler.DeviceLostError`
  or hanging the dispatch), transiently or permanently, optionally healing
  later — exercising the scheduler's watchdog retry, slot evacuation, mesh
  shrink and regrow.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.integrands import ParamIntegrand

# The loss/timeout exceptions live with the scheduler's watchdog (this module
# imports the scheduler, so the reverse import would be circular); re-exported
# here because chaos tests naturally look for them next to the injectors.
from repro.service.scheduler import DeviceLostError, DispatchTimeout, QuadRequest

__all__ = [
    "NAN_SENTINEL",
    "SimulatedCrash",
    "DeviceLostError",
    "DispatchTimeout",
    "DeviceDown",
    "nan_family",
    "poison_theta",
    "corrupt_slot",
    "corrupt_slot_hook",
    "crash_at",
    "storm_requests",
]

#: Theta magnitude that triggers the NaN wrapper.  Large enough that no
#: sampled problem instance ever reaches it, small enough to stay finite in
#: float64 (so the *sentinel itself* never overflows before the check).
NAN_SENTINEL = 1e300


class SimulatedCrash(RuntimeError):
    """Raised by fault hooks to kill the serve loop at a deterministic point."""


def nan_family(family: ParamIntegrand) -> ParamIntegrand:
    """Wrap ``family`` so sentinel-carrying thetas evaluate to NaN.

    The poison travels *in the request's theta*, so one wrapped family serves
    healthy and poisoned requests side by side in the same vmapped fleet —
    exactly the scenario the quarantine must survive.
    """
    base = family.fn

    def fn(x, theta):
        poisoned = jnp.zeros((), bool)
        for leaf in jax.tree_util.tree_leaves(theta):
            poisoned = poisoned | jnp.any(jnp.asarray(leaf) >= NAN_SENTINEL)
        return jnp.where(poisoned, jnp.nan, base(x, theta))

    return dataclasses.replace(
        family,
        name=family.name + "+nanfault",
        fn=fn,
        description=f"{family.name} with sentinel-triggered NaN injection",
    )


def poison_theta(theta):
    """Plant :data:`NAN_SENTINEL` in the first leaf of a theta pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(theta)
    first = np.full_like(np.asarray(leaves[0], np.float64), NAN_SENTINEL)
    return jax.tree_util.tree_unflatten(treedef, [first] + leaves[1:])


def corrupt_slot(state, slot: int):
    """Overwrite one slot's estimator state with NaN, preserving placement.

    For the cubature :class:`~repro.service.batch_engine.BatchState` the
    slot's *durable* state is poisoned — region centers (every active region
    is re-split and re-evaluated each iteration, so transient per-region
    estimates would simply be recomputed from clean geometry) and the
    finalised-integral accumulator; for the MC
    :class:`~repro.mc.engine.VegasBatchState` the slot's weighted-average
    accumulators are.  The replacement arrays are re-placed with the
    original leaves' shardings, so a corrupted fleet state stays valid input
    for the next fused dispatch on any mesh.
    """

    def poison(leaf):
        host = np.array(jax.device_get(leaf))
        host[slot] = np.nan
        return jax.device_put(host, leaf.sharding)

    if hasattr(state, "regions"):  # cubature fleet
        regions = dataclasses.replace(
            state.regions,
            centers=poison(state.regions.centers),
            fin_integral=poison(state.regions.fin_integral),
        )
        return dataclasses.replace(state, regions=regions)
    if hasattr(state, "mc"):  # vegas fleet
        mc = dataclasses.replace(
            state.mc,
            sum_wi=poison(state.mc.sum_wi),
            sum_wi2=poison(state.mc.sum_wi2),
        )
        return dataclasses.replace(state, mc=mc)
    raise TypeError(f"unrecognised fleet state {type(state).__name__}")


def corrupt_slot_hook(slot: int, at_iteration: int, req_id: Optional[int] = None):
    """``on_tick`` hook: corrupt ``slot`` once, at the first tick >= threshold.

    With ``req_id`` set, the hook holds fire until that request occupies the
    slot — so the injection cannot land on whatever request was admitted
    into the slot after the intended victim drained.
    """
    fired = {"done": False}

    def hook(it, state, slot_req):
        if fired["done"] or it < at_iteration:
            return None
        req = slot_req[slot]
        if req is None or (req_id is not None and req.req_id != req_id):
            return None
        fired["done"] = True
        return corrupt_slot(state, slot)

    return hook


@dataclasses.dataclass
class DeviceDown:
    """Deterministic device-loss injector for the scheduler's watchdog.

    Plugs into ``BatchScheduler(fault_injector=...)``: the scheduler calls
    :meth:`pre_dispatch` at every dispatch boundary (before the engine
    consumes the state, so retry/evacuation read intact buffers) and probes
    :meth:`healthy` to attribute hangs and to decide regrowth.

    ``device`` is an index into the engine's *original* mesh.  From
    iteration ``at_tick`` the device is down:

    - ``transient_failures=0`` (default): permanently — until
      ``restore_at_tick``, if set, after which :meth:`healthy` reports the
      device back and a later admission tick regrows the mesh onto it;
    - ``transient_failures=k``: for exactly ``k`` dispatch attempts, then
      healthy again — a watchdog with ``max_dispatch_retries >= k`` rides
      it out with the run bit-identical to a fault-free one.

    ``mode="raise"`` raises :class:`DeviceLostError` (a detectable fault);
    ``mode="hang"`` sleeps ``hang_s`` instead (a wedged dispatch — pair it
    with ``dispatch_timeout_s`` so the watchdog converts the hang into a
    :class:`DispatchTimeout`).

    Failure behaviour is a pure function of the dispatch sequence — no wall
    clock, no randomness — so a chaos run replays decision-for-decision.
    """

    device: int
    at_tick: int
    transient_failures: int = 0  # 0 = permanent
    restore_at_tick: Optional[int] = None  # heal point (permanent mode)
    mode: str = "raise"  # "raise" | "hang"
    hang_s: float = 30.0
    _fired: int = dataclasses.field(default=0, init=False, repr=False)

    def __post_init__(self):
        if self.mode not in ("raise", "hang"):
            raise ValueError(f"mode must be 'raise' or 'hang', got {self.mode!r}")

    def _down(self, it: int) -> bool:
        if it < self.at_tick:
            return False
        if self.transient_failures > 0:
            return self._fired < self.transient_failures
        if self.restore_at_tick is not None and it >= self.restore_at_tick:
            return False
        return True

    def healthy(self, device: int, it: int) -> bool:
        """Scheduler probe: is ``device`` serving at iteration ``it``?"""
        return device != self.device or not self._down(it)

    def pre_dispatch(self, it: int, device_indices: Sequence[int]) -> None:
        """Fail the dispatch when the down device is part of the mesh."""
        if self.device not in device_indices or not self._down(it):
            return
        self._fired += 1
        if self.mode == "hang":
            time.sleep(self.hang_s)
            return
        raise DeviceLostError(
            self.device,
            f"injected device loss: device {self.device} at iteration {it}",
        )


def crash_at(at_iteration: int):
    """``on_tick`` hook raising :class:`SimulatedCrash` at a fixed iteration."""

    def hook(it, state, slot_req):
        if it >= at_iteration:
            raise SimulatedCrash(f"injected crash at iteration {it}")
        return None

    return hook


def storm_requests(
    family: ParamIntegrand,
    d: int,
    n: int,
    seed: int = 0,
    rel_tol: Optional[float] = None,
    abs_tol: Optional[float] = None,
    req_id_base: int = 0,
) -> Iterator[QuadRequest]:
    """A deterministic burst of ``n`` sampled problem instances."""
    rng = np.random.default_rng(seed)
    for i in range(n):
        yield QuadRequest(
            req_id=req_id_base + i,
            theta=family.sample_theta(d, rng),
            rel_tol=rel_tol,
            abs_tol=abs_tol,
        )
