"""Continuous-batching scheduler for the batch quadrature engine.

The host-side loop that turns the fixed-shape :class:`BatchEngine` into a
service: a FIFO request queue feeds ``cfg.batch_slots`` slots; every
``cfg.admit_every`` iterations freed slots are refilled from the queue
(mid-flight — the other slots keep refining through the same compiled step),
and finished slots are collected and yielded as :class:`QuadResult`\\ s as
soon as their ``done`` flag flips, in convergence order rather than
submission order.

The engine is driven through its fused :meth:`~BatchEngine.run` protocol:
up to ``cfg.sync_every`` iterations execute per dispatch and the dispatch
exits early — from an on-device psum of per-slot done masks — the moment any
slot finishes, so the host observes every collection at its exact iteration.
The scheduler additionally caps a dispatch so it cannot run past the next
``admit_every`` tick while an admission is pending.  Together these make the
fused loop replay the unfused per-iteration loop decision-for-decision:
results (including ``admitted_at`` / ``finished_at``) are bit-identical at
any ``sync_every`` and any device count.

On a sharded engine the scheduler is also mesh-aware: admissions target the
device that owns the freed slot (free slots are filled on the least-loaded
device first, so fresh problems spread across the mesh), and the migration
records the engine emits when its cyclic rebalancer moves a problem between
devices are replayed onto the host's slot -> request map in iteration order.

Termination taxonomy per request (mirrors ``AdaptiveResult.status``):

- ``converged`` — error estimate under the request's budget;
- ``capacity`` — the slot's region store saturated (``overflowed``) and
  stayed unconverged for ``cfg.evict_patience`` further iterations: the
  engine freezes it and the scheduler *evicts* it with its best-effort
  estimate so the slot can serve the rest of the queue instead of grinding
  a hopeless problem (transient saturation that converges within the grace
  period keeps exact parity with the serial driver);
- ``no_active`` / ``max_iters`` — degenerate population / iteration cap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Iterator, Optional, Union

import jax
import numpy as np

from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.batch_engine import BatchEngine, BatchState


def make_engine(
    cfg: QuadratureConfig,
    family: Union[ParamIntegrand, str, None] = None,
    mesh=None,
    devices=None,
):
    """Engine for ``cfg``'s resolved backend.

    The service fronts two engine pools behind one scheduler protocol
    (``init``/``admit``/``release``/fused ``run`` + ``status_of``): the
    deterministic cubature :class:`BatchEngine` and the Monte Carlo
    :class:`~repro.mc.engine.VegasBatchEngine` — ``backend="auto"`` picks by
    the problem dimension, so high-d fleets are admitted through MC instead
    of being rejected by region-store explosion.
    """
    if cfg.resolved_backend() == "vegas":
        from repro.mc.engine import VegasBatchEngine

        return VegasBatchEngine(cfg, family, mesh=mesh, devices=devices)
    return BatchEngine(cfg, family, mesh=mesh, devices=devices)


@dataclasses.dataclass(frozen=True)
class QuadRequest:
    """One integration problem: a theta of the engine's family + tolerances."""

    req_id: int
    theta: Any  # pytree matching the family's theta_fields, leaves (d,)
    rel_tol: Optional[float] = None  # None -> cfg default
    abs_tol: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class QuadResult:
    """Terminal state of one request (statuses as in AdaptiveResult)."""

    req_id: int
    integral: float
    error: float
    status: str  # converged | capacity | no_active | max_iters
    iterations: int  # per-slot adaptive iterations spent on this problem
    n_evals: float  # integrand evaluations spent on this problem
    admitted_at: int  # scheduler iteration at which the slot was filled
    finished_at: int  # scheduler iteration at which done flipped on

    def summary(self) -> str:
        return (
            f"req={self.req_id} I={self.integral:.15e} eps={self.error:.3e} "
            f"[{self.status}] iters={self.iterations} evals={self.n_evals:.3g}"
        )


class BatchScheduler:
    """Drives a :class:`BatchEngine` over an arbitrary stream of requests.

    After :meth:`serve` completes, :attr:`last_stats` holds host-loop
    counters for the run: ``iterations`` (fleet iterations), ``dispatches``
    (fused engine launches) and ``migrations`` (problems moved between
    devices by the cyclic rebalancer).
    """

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        engine: Optional[BatchEngine] = None,
        mesh=None,
        devices=None,
    ):
        if engine is not None:
            if mesh is not None or devices is not None:
                raise ValueError(
                    "pass mesh/devices to the BatchEngine, not alongside an "
                    "explicit engine: the engine's mesh is fixed at "
                    "construction and a conflicting argument here would be "
                    "silently ignored"
                )
            self.engine = engine
        else:
            self.engine = make_engine(cfg, family, mesh=mesh, devices=devices)
        self.cfg = self.engine.cfg
        self.last_stats: dict = {"iterations": 0, "dispatches": 0, "migrations": 0}

    def serve(self, requests: Iterable[QuadRequest]) -> Iterator[QuadResult]:
        """Run the fleet to completion, yielding results as slots converge.

        ``requests`` may be any iterable (including a generator — it is only
        pulled from when a slot is free, so an unbounded stream backpressures
        naturally).  Every request yields exactly one result.
        """
        engine = self.engine
        cfg = self.cfg
        B = engine.n_slots
        per_dev = engine.slots_per_device
        pending = iter(requests)
        exhausted = False  # the iterator signalled StopIteration
        slot_req: list[Optional[QuadRequest]] = [None] * B
        slot_admitted = np.zeros(B, np.int64)
        stats = {"iterations": 0, "dispatches": 0, "migrations": 0}
        self.last_stats = stats
        state = engine.init()
        it = 0

        def pull() -> Optional[QuadRequest]:
            # Requests are pulled ONLY here, from admission passes — never
            # speculatively — so a generator that derives its next request
            # from results yielded so far sees exactly the per-iteration
            # loop's pull points, and an unbounded stream backpressures on
            # slot availability.
            nonlocal exhausted
            if exhausted:
                return None
            req = next(pending, None)
            if req is None:
                exhausted = True
            return req

        def admission_order() -> list[int]:
            """Free slots, least-loaded device first (plain slot order on one
            device, which is exactly the legacy single-device fill order)."""
            free = [s for s in range(B) if slot_req[s] is None]
            if engine.n_devices == 1:
                return free
            load = [0] * engine.n_devices
            for s in range(B):
                if slot_req[s] is not None:
                    load[s // per_dev] += 1
            # admitting onto a device raises its load for the next pick, so
            # a burst of admissions round-robins across the drained devices
            order: list[int] = []
            free_per_dev = [[s for s in free if s // per_dev == d] for d in range(engine.n_devices)]
            for _ in free:
                dev = min(
                    (d for d in range(engine.n_devices) if free_per_dev[d]),
                    key=lambda d: (load[d], d),
                )
                order.append(free_per_dev[dev].pop(0))
                load[dev] += 1
            return order

        def admit_free_slots(state: BatchState) -> BatchState:
            for slot in admission_order():
                req = pull()
                if req is None:
                    break
                state = engine.admit(
                    state, slot, req.theta, req.rel_tol, req.abs_tol
                )
                slot_req[slot] = req
                slot_admitted[slot] = it
            return state

        def apply_moves(rows: np.ndarray) -> None:
            """Replay one iteration's device-side migrations onto the host
            map.  Within a round sources (live slots) and destinations
            (previously free slots) are disjoint, so copy-then-clear is
            exact."""
            valid = [(int(s), int(d)) for s, d in rows if s >= 0]
            if not valid:
                return
            snapshot_req = list(slot_req)
            snapshot_adm = slot_admitted.copy()
            for src, dst in valid:
                assert snapshot_req[src] is not None, (src, dst)
                slot_req[dst] = snapshot_req[src]
                slot_admitted[dst] = snapshot_adm[src]
                slot_req[src] = None
            stats["migrations"] += len(valid)

        state = admit_free_slots(state)
        while any(r is not None for r in slot_req):
            # A dispatch may not run past the next admit tick while an
            # admission may be pending (free slot + a queue not yet known to
            # be exhausted) — the tick is a host decision the device cannot
            # replay.  Whether the queue actually still holds a request is
            # only discovered AT the tick, preserving the unfused loop's
            # exact pull timing; once the iterator is exhausted, full-length
            # dispatches resume for the drain phase.
            max_steps = cfg.sync_every
            if not exhausted and any(r is None for r in slot_req):
                max_steps = min(max_steps, cfg.admit_every - it % cfg.admit_every)
            state, ms, executed, moved = engine.run(state, max_steps, it)
            ms, executed, moved = jax.device_get((ms, executed, moved))
            k = int(np.sum(executed))
            assert k >= 1, "fused dispatch executed no iterations"
            stats["dispatches"] += 1
            stats["iterations"] += k
            for t in range(k - 1):
                it += 1
                apply_moves(moved[t])
            it += 1
            done = ms["done"][k - 1]
            occupied = ms["occupied"][k - 1]
            finished = [
                (slot_req[s].req_id, s)
                for s in range(B)
                if done[s] and occupied[s] and slot_req[s] is not None
            ]
            # req_id order: deterministic across device counts (collection
            # within one iteration has no inherent slot order anyway)
            for req_id, slot in sorted(finished):
                yield QuadResult(
                    req_id=req_id,
                    integral=float(ms["integral"][k - 1][slot]),
                    error=float(ms["error"][k - 1][slot]),
                    status=engine.status_of(
                        bool(ms["converged"][k - 1][slot]),
                        int(ms["n_active"][k - 1][slot]),
                        int(ms["it"][k - 1][slot]),
                        bool(ms["overflowed"][k - 1][slot]),
                    ),
                    iterations=int(ms["it"][k - 1][slot]),
                    n_evals=float(ms["n_evals"][k - 1][slot]),
                    admitted_at=int(slot_admitted[slot]),
                    finished_at=it,
                )
            # migrations of the final executed iteration happened *after* its
            # metrics snapshot (and done slots never migrate), so the map
            # update follows collection
            apply_moves(moved[k - 1])
            for _, slot in finished:
                state = engine.release(state, slot)
                slot_req[slot] = None
            # Admit on the configured cadence — but never let the fleet go
            # idle with work still queued: if every slot just drained we
            # admit immediately rather than spinning (or exiting) until the
            # next admit tick.
            if it % cfg.admit_every == 0 or all(r is None for r in slot_req):
                state = admit_free_slots(state)
        # drain: nothing in flight, so nothing may remain unadmitted
        leftover = pull()
        if leftover is not None:  # pragma: no cover - invariant guard
            raise RuntimeError(
                f"scheduler exited with queued requests (req_id={leftover.req_id})"
            )
