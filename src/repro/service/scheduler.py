"""Continuous-batching scheduler for the batch quadrature engine.

The host-side loop that turns the fixed-shape :class:`BatchEngine` into a
service: a FIFO request queue feeds ``cfg.batch_slots`` slots; every
``cfg.admit_every`` iterations freed slots are refilled from the queue
(mid-flight — the other slots keep refining through the same compiled step),
and finished slots are collected and yielded as :class:`QuadResult`\\ s as
soon as their ``done`` flag flips, in convergence order rather than
submission order.

The engine is driven through its fused :meth:`~BatchEngine.run` protocol:
up to ``cfg.sync_every`` iterations execute per dispatch and the dispatch
exits early — from an on-device psum of per-slot done masks — the moment any
slot finishes, so the host observes every collection at its exact iteration.
The scheduler additionally caps a dispatch so it cannot run past the next
``admit_every`` tick while an admission is pending.  Together these make the
fused loop replay the unfused per-iteration loop decision-for-decision:
results (including ``admitted_at`` / ``finished_at``) are bit-identical at
any ``sync_every`` and any device count.

On a sharded engine the scheduler is also mesh-aware: admissions target the
device that owns the freed slot (free slots are filled on the least-loaded
device first, so fresh problems spread across the mesh), and the migration
records the engine emits when its cyclic rebalancer moves a problem between
devices are replayed onto the host's slot -> request map in iteration order.

Termination taxonomy per request (mirrors ``AdaptiveResult.status``):

- ``converged`` — error estimate under the request's budget;
- ``capacity`` — the slot's region store saturated (``overflowed``) and
  stayed unconverged for ``cfg.evict_patience`` further iterations: the
  engine freezes it and the scheduler *evicts* it with its best-effort
  estimate so the slot can serve the rest of the queue instead of grinding
  a hopeless problem (transient saturation that converges within the grace
  period keeps exact parity with the serial driver);
- ``no_active`` / ``max_iters`` — degenerate population / iteration cap;
- ``nonfinite`` — the slot produced NaN/Inf estimates; the engine quarantined
  the offending regions (zeroed their contributions, deactivated them) the
  same iteration, so the rest of the fleet's psum'd reductions never see the
  poison, and the scheduler collects the slot with its best-effort estimate;
- ``deadline`` — the request's SLO (``deadline_s`` wall clock and/or
  ``max_evals`` evaluation budget) expired at a dispatch boundary: the
  scheduler evicts the slot with its best-effort partial estimate instead of
  letting one slow problem hold a slot indefinitely.

Graceful degradation on top of this taxonomy (fallback re-routing of
``capacity``/``nonfinite`` evictions to the VEGAS pool, looser-tolerance
retries) lives in :mod:`repro.service.routing`; service-level
checkpoint/resume in :mod:`repro.service.checkpoint`.

The scheduler is also elastic in the fleet-topology dimension: every
dispatch runs under a host-side watchdog (:class:`DispatchTimeout` /
:class:`DeviceLostError`, bounded retries with exponential backoff), and a
device declared permanently failed is evacuated — snapshot-covered slots
rewind to the newest service checkpoint, uncovered requests re-enter the
admission queue with provenance — before the engine is rebuilt on the
largest surviving sub-mesh and, once the device heals, regrown.  See
DESIGN.md §6 for the failure model and the bit-identity guarantees.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Iterable, Iterator, Optional, Union

import jax
import numpy as np

from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.batch_engine import BatchEngine, BatchState
from repro.telemetry import NULL, ServiceStats


class DeviceLostError(RuntimeError):
    """A device failed permanently (retries exhausted, or mesh not elastic).

    ``device`` is the failing device's global index in the engine's
    *original* mesh, or ``None`` when the watchdog could not attribute the
    fault to a specific device.  Raised by injectors
    (:class:`repro.service.faults.DeviceDown`) to simulate the loss, and
    re-raised by the scheduler only when recovery is impossible — a
    single-device engine has nowhere to evacuate to.
    """

    def __init__(self, device: Optional[int], message: str):
        super().__init__(message)
        self.device = device


class DispatchTimeout(RuntimeError):
    """A fused dispatch exceeded the watchdog's ``dispatch_timeout_s``.

    Unlike :class:`DeviceLostError` it carries no device attribution — a
    hang looks the same from the host regardless of which device wedged —
    so the scheduler falls back to the injector's ``healthy`` probe (or
    gives up) to pick the device to declare failed.
    """


def _call_with_timeout(fn: Callable, timeout_s: Optional[float]):
    """Run ``fn()`` under a wall-clock watchdog.

    With a timeout the call runs on a daemon thread and a ``join`` bounds
    the wait: a wedged dispatch raises :class:`DispatchTimeout` on the host
    and the stuck thread is abandoned.  Exceptions from ``fn`` itself
    propagate unchanged either way.  Retrying after a timeout presumes the
    abandoned attempt never consumed the state buffers — true of the
    deterministic injectors, which stall in the pre-dispatch hook before
    the engine touches the state.
    """
    if timeout_s is None:
        return fn()
    box: dict = {}

    def target():
        try:
            box["value"] = fn()
        except BaseException as err:  # noqa: BLE001 - re-raised on the host
            box["error"] = err

    worker = threading.Thread(target=target, daemon=True, name="dispatch-watchdog")
    worker.start()
    worker.join(timeout_s)
    if worker.is_alive():
        raise DispatchTimeout(
            f"fused dispatch still running after {timeout_s}s watchdog"
        )
    if "error" in box:
        raise box["error"]
    return box["value"]


def make_engine(
    cfg: QuadratureConfig,
    family: Union[ParamIntegrand, str, None] = None,
    mesh=None,
    devices=None,
    recorder=NULL,
):
    """Engine for ``cfg``'s resolved backend.

    The service fronts two engine pools behind one scheduler protocol
    (``init``/``admit``/``release``/fused ``run`` + ``status_of``): the
    deterministic cubature :class:`BatchEngine` and the Monte Carlo
    :class:`~repro.mc.engine.VegasBatchEngine` — ``backend="auto"`` picks by
    the problem dimension, so high-d fleets are admitted through MC instead
    of being rejected by region-store explosion.
    """
    if cfg.resolved_backend() == "vegas":
        from repro.mc.engine import VegasBatchEngine

        return VegasBatchEngine(
            cfg, family, mesh=mesh, devices=devices, recorder=recorder
        )
    return BatchEngine(cfg, family, mesh=mesh, devices=devices, recorder=recorder)


@dataclasses.dataclass(frozen=True)
class QuadRequest:
    """One integration problem: a theta of the engine's family + tolerances.

    ``deadline_s`` / ``max_evals`` are best-effort SLOs, checked at dispatch
    boundaries (the host only observes slot metrics between fused launches):
    once either budget is exhausted the slot is evicted with its current
    partial estimate and status ``deadline`` instead of holding the slot.
    ``max_evals`` is deterministic (counted in integrand evaluations);
    ``deadline_s`` is wall clock measured from admission.
    """

    req_id: int
    theta: Any  # pytree matching the family's theta_fields, leaves (d,)
    rel_tol: Optional[float] = None  # None -> cfg default
    abs_tol: Optional[float] = None
    deadline_s: Optional[float] = None  # wall-clock budget from admission
    max_evals: Optional[float] = None  # integrand-evaluation budget


@dataclasses.dataclass(frozen=True)
class QuadResult:
    """Terminal state of one request (statuses as in AdaptiveResult).

    ``backend``/``attempts``/``retried_from`` record attempt provenance:
    which engine pool produced this estimate, how many admissions the
    request consumed in total, and — for re-routed/retried requests — the
    terminal status of the attempt that triggered the re-route (see
    :class:`repro.service.routing.GracefulScheduler`).  ``evacuated``
    records device-loss provenance: ``"snapshot"`` when the request's slot
    was recovered from the newest service checkpoint after its device
    failed (its trajectory rewound and replayed, still bit-identical),
    ``"readmit"`` when no snapshot covered the slot and the request was
    re-admitted from scratch (``attempts`` bumps and ``retried_from`` is
    ``"device_lost"``), ``None`` for requests no device failure touched.
    """

    req_id: int
    integral: float
    error: float
    status: str  # converged | capacity | no_active | max_iters | nonfinite | deadline
    iterations: int  # per-slot adaptive iterations spent on this problem
    n_evals: float  # integrand evaluations spent on this problem
    admitted_at: int  # scheduler iteration at which the slot was filled
    finished_at: int  # scheduler iteration at which done flipped on
    backend: str = "cubature"  # engine pool that produced this estimate
    attempts: int = 1  # admissions consumed (1 = first attempt)
    retried_from: Optional[str] = None  # prior attempt's terminal status
    evacuated: Optional[str] = None  # device-loss recovery: snapshot | readmit

    def summary(self) -> str:
        via = f" via={self.backend}" if self.attempts > 1 else ""
        evac = f" evac={self.evacuated}" if self.evacuated else ""
        return (
            f"req={self.req_id} I={self.integral:.15e} eps={self.error:.3e} "
            f"[{self.status}] iters={self.iterations} evals={self.n_evals:.3g}"
            f"{via}{evac}"
        )


def encode_request(req: QuadRequest) -> dict:
    """JSON-able form of a request (theta leaves as float64 lists).

    ``json`` serialises float64 via ``repr``, which round-trips bit-exactly,
    so a decode of an encode reconstructs the identical problem — the
    service checkpoint's resume-parity argument rests on this.
    """
    return {
        "req_id": int(req.req_id),
        "theta": jax.tree.map(
            lambda x: np.asarray(x, np.float64).tolist(), req.theta
        ),
        "rel_tol": None if req.rel_tol is None else float(req.rel_tol),
        "abs_tol": None if req.abs_tol is None else float(req.abs_tol),
        "deadline_s": None if req.deadline_s is None else float(req.deadline_s),
        "max_evals": None if req.max_evals is None else float(req.max_evals),
    }


def decode_request(obj: dict, theta_template) -> QuadRequest:
    """Inverse of :func:`encode_request`.

    ``theta_template`` (the engine's) supplies the pytree structure so the
    stored nested lists land as leaves of the right shape rather than being
    re-flattened as pytrees themselves.
    """
    theta = jax.tree.map(
        lambda t, v: np.asarray(v, np.float64).reshape(np.shape(t)),
        theta_template,
        obj["theta"],
    )
    return QuadRequest(
        req_id=int(obj["req_id"]),
        theta=theta,
        rel_tol=obj.get("rel_tol"),
        abs_tol=obj.get("abs_tol"),
        deadline_s=obj.get("deadline_s"),
        max_evals=obj.get("max_evals"),
    )


class BatchScheduler:
    """Drives a :class:`BatchEngine` over an arbitrary stream of requests.

    After :meth:`serve` completes, :attr:`last_stats` is a dict view of the
    run's :class:`~repro.telemetry.ServiceStats` — ``iterations`` (fleet
    iterations), ``dispatches`` (fused engine launches), ``admissions``,
    ``collections``, ``migrations`` (problems moved between devices by the
    cyclic rebalancer), ``quarantines`` (slots collected with a
    ``nonfinite`` status), ``deadlines`` (slots evicted on an expired SLO),
    ``checkpoints``, and the elastic-fleet counters ``dispatch_retries``,
    ``evacuations``, ``mesh_shrinks`` and ``mesh_regrows``.

    ``recorder`` (a :class:`repro.telemetry.Recorder`; default the no-op
    :data:`~repro.telemetry.NULL`) receives the structured event stream:
    spans around compile/dispatch/admit/collect/checkpoint, per-device
    ``service.n_live`` occupancy gauges at every executed iteration, and
    flow events for slot migrations.  Everything is recorded host-side at
    dispatch boundaries, so telemetry on/off cannot change any result bit
    (see DESIGN.md §8).

    ``checkpointer`` (a :class:`repro.service.checkpoint.ServiceCheckpointer`)
    snapshots the stacked engine state + the slot -> request map every
    ``checkpoint_every`` admission ticks; ``serve(resume=True)`` restores the
    latest snapshot and replays from it — bit-identically for slots the
    crash did not touch.  ``on_tick(it, state, slot_req)`` is a host hook
    called at every dispatch boundary (fault injection, external monitoring);
    it may return a replacement state pytree or ``None``.

    **Elastic fleet resilience** (DESIGN.md §6): every dispatch runs under a
    host-side watchdog.  A :class:`DeviceLostError` from ``fault_injector``'s
    pre-dispatch hook (see :class:`repro.service.faults.DeviceDown`) or a
    :class:`DispatchTimeout` past ``dispatch_timeout_s`` is retried up to
    ``max_dispatch_retries`` times with exponential backoff
    (``retry_backoff_s * 2**attempt``) — transient faults recover with the
    run bit-identical to a fault-free one.  When retries exhaust, the device
    is declared failed: its slots are evacuated (recovered from the newest
    service snapshot when it covers them, else their requests re-admitted
    with ``attempts``/``retried_from``/``evacuated`` provenance), the engine
    is rebuilt on the largest surviving sub-mesh dividing ``batch_slots``,
    and the fleet keeps serving.  A later admission tick regrows the mesh
    when the injector reports the device healthy again.  All detection and
    recovery happens between dispatches — no traced code changes.
    """

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        engine: Optional[BatchEngine] = None,
        mesh=None,
        devices=None,
        checkpointer=None,
        checkpoint_every: int = 0,
        on_tick: Optional[Callable] = None,
        recorder=NULL,
        fault_injector=None,
        max_dispatch_retries: int = 2,
        dispatch_timeout_s: Optional[float] = None,
        retry_backoff_s: float = 0.1,
    ):
        self.recorder = recorder
        if engine is not None:
            if mesh is not None or devices is not None:
                raise ValueError(
                    "pass mesh/devices to the BatchEngine, not alongside an "
                    "explicit engine: the engine's mesh is fixed at "
                    "construction and a conflicting argument here would be "
                    "silently ignored"
                )
            self.engine = engine
        else:
            self.engine = make_engine(
                cfg, family, mesh=mesh, devices=devices, recorder=recorder
            )
        self.cfg = self.engine.cfg
        if checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if checkpoint_every and checkpointer is None:
            raise ValueError("checkpoint_every > 0 requires a checkpointer")
        if max_dispatch_retries < 0:
            raise ValueError(
                f"max_dispatch_retries must be >= 0, got {max_dispatch_retries}"
            )
        if dispatch_timeout_s is not None and dispatch_timeout_s <= 0:
            raise ValueError(
                f"dispatch_timeout_s must be positive, got {dispatch_timeout_s}"
            )
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        self.on_tick = on_tick
        self.fault_injector = fault_injector
        self.max_dispatch_retries = max_dispatch_retries
        self.dispatch_timeout_s = dispatch_timeout_s
        self.retry_backoff_s = retry_backoff_s
        self._stats = ServiceStats()
        self._warm = False  # first-ever dispatch traces + compiles the step
        # Elastic-mesh bookkeeping.  Devices are identified by their index in
        # the engine's ORIGINAL mesh for the whole scheduler lifetime —
        # injector device ids, telemetry attrs, and the regrow target all
        # speak this namespace.  A single-device engine is not elastic
        # (nowhere to evacuate to): _all_devices stays None and a permanent
        # device loss is fatal.
        mesh = getattr(self.engine, "mesh", None)
        self._all_devices = list(mesh.devices.flat) if mesh is not None else None
        self._current_devs = list(range(self.engine.n_devices))
        self._failed: set = set()

    @property
    def last_stats(self) -> dict:
        """Dict view of the latest run's :class:`ServiceStats` (compat)."""
        return self._stats.as_dict()

    # --- elastic-mesh plumbing -----------------------------------------------

    def _healthy_mesh(self) -> list:
        """Largest sub-mesh of healthy devices whose size divides the slot
        count, as original-mesh indices in original order.

        ``batch_slots % n_devices == 0`` is the engine's contiguous-block
        placement invariant, so losing one device out of e.g. 4 with 8 slots
        shrinks to 2 devices, idling one healthy device until a regrow.
        """
        healthy = [
            gi for gi in range(len(self._all_devices)) if gi not in self._failed
        ]
        if not healthy:
            raise DeviceLostError(None, "every device in the mesh has failed")
        B = self.engine.n_slots
        m = max(k for k in range(1, len(healthy) + 1) if B % k == 0)
        return healthy[:m]

    def _rebuild_engine(self, dev_indices: list):
        """Rebuild the engine on the given original-mesh device indices.

        The compiled step/admit/release are rebuilt for the new device count
        (``redistribution.make_schedule``/``ring_perms`` are re-derived from
        it inside the engine), so the next dispatch re-traces — the warm
        flag resets and the trace shows a fresh ``service.compile`` span.
        """
        devices = [self._all_devices[i] for i in dev_indices]
        self.engine = make_engine(
            self.cfg, self.engine.family, devices=devices, recorder=self.recorder
        )
        self._current_devs = list(dev_indices)
        self._warm = False
        return self.engine

    def _attribute_fault(self, err: Exception, it: int) -> Optional[int]:
        """Best-effort mapping of a dispatch fault to an original-mesh device
        index: the error's own attribution first, else the injector's
        ``healthy`` probe over the devices in the current mesh."""
        dev = getattr(err, "device", None)
        if dev is not None:
            return int(dev)
        probe = getattr(self.fault_injector, "healthy", None)
        if probe is not None:
            for gi in self._current_devs:
                if not probe(gi, it):
                    return gi
        return None

    def serve(
        self, requests: Iterable[QuadRequest], resume: bool = False
    ) -> Iterator[QuadResult]:
        """Run the fleet to completion, yielding results as slots converge.

        ``requests`` may be any iterable (including a generator — it is only
        pulled from when a slot is free, so an unbounded stream backpressures
        naturally).  Every request yields exactly one result.

        With ``resume=True`` the latest service checkpoint is restored first:
        in-flight slots resume mid-refinement, requests the crashed run had
        already pulled are skipped from ``requests`` (the caller re-supplies
        the same stream), and requests that finished *after* the restored
        snapshot are served again — deterministically, so the duplicates are
        bit-identical to the results the crashed run already yielded.
        """
        engine = self.engine
        cfg = self.cfg
        B = engine.n_slots
        pending = iter(requests)
        exhausted = False  # the iterator signalled StopIteration
        slot_req: list[Optional[QuadRequest]] = [None] * B
        slot_admitted = np.zeros(B, np.int64)
        slot_wall = [0.0] * B  # admission wall clock, for deadline_s
        pulled_ids: set[int] = set()
        skip_ids: set[int] = set()
        # Device-loss bookkeeping: requests bumped off a failed device wait
        # in retry_queue (served before the pending iterator, preserving
        # admission-order determinism), and the evac_* maps carry their
        # provenance into the eventual QuadResult.
        retry_queue: deque = deque()
        evac_attempts: dict = {}  # req_id -> extra admissions consumed
        evac_from: dict = {}  # req_id -> status that triggered the retry
        evac_kind: dict = {}  # req_id -> "snapshot" | "readmit"
        rec = self.recorder
        stats = ServiceStats()
        self._stats = stats

        def bump(counter: str, n: int = 1) -> None:
            # one typed schema + one event stream: every host-loop counter
            # bump lands in ServiceStats AND (when enabled) the recorder
            stats.add(counter, n)
            rec.count(f"service.{counter}", n)

        state = engine.init()
        it = 0
        ticks = 0  # admission passes completed (checkpoint cadence unit)
        rec.event(
            "service.start",
            backend=engine.backend,
            slots=B,
            devices=engine.n_devices,
            sync_every=cfg.sync_every,
            admit_every=cfg.admit_every,
            resume=resume,
        )

        if resume:
            if self.checkpointer is None:
                raise ValueError("resume=True requires a checkpointer")
            state, meta = self.checkpointer.restore(engine)
            it = int(meta["it"])
            ticks = int(meta["ticks"])
            stats.merge(ServiceStats.from_dict(meta["stats"]))
            pulled_ids = set(meta["pulled_ids"])
            skip_ids = set(pulled_ids)
            for entry in meta["slots"]:
                slot = int(entry["slot"])
                slot_req[slot] = decode_request(entry["req"], engine.theta_template)
                slot_admitted[slot] = int(entry["admitted_at"])
                slot_wall[slot] = time.monotonic()  # wall deadlines restart

        def pull() -> Optional[QuadRequest]:
            # Requests are pulled ONLY here, from admission passes — never
            # speculatively — so a generator that derives its next request
            # from results yielded so far sees exactly the per-iteration
            # loop's pull points, and an unbounded stream backpressures on
            # slot availability.  On resume, requests the crashed run had
            # already pulled are skipped so the replayed stream lines up
            # with the restored slot map.  Evacuated requests (device loss)
            # re-enter here, ahead of the never-admitted stream.
            nonlocal exhausted
            if retry_queue:
                return retry_queue.popleft()
            if exhausted:
                return None
            req = next(pending, None)
            while req is not None and req.req_id in skip_ids:
                req = next(pending, None)
            if req is None:
                exhausted = True
            else:
                pulled_ids.add(req.req_id)
            return req

        def admission_order() -> list[int]:
            """Free slots, least-loaded device first (plain slot order on one
            device, which is exactly the legacy single-device fill order)."""
            free = [s for s in range(B) if slot_req[s] is None]
            if engine.n_devices == 1:
                return free
            per_dev = engine.slots_per_device
            load = [0] * engine.n_devices
            for s in range(B):
                if slot_req[s] is not None:
                    load[s // per_dev] += 1
            # admitting onto a device raises its load for the next pick, so
            # a burst of admissions round-robins across the drained devices
            order: list[int] = []
            free_per_dev = [[s for s in free if s // per_dev == d] for d in range(engine.n_devices)]
            for _ in free:
                dev = min(
                    (d for d in range(engine.n_devices) if free_per_dev[d]),
                    key=lambda d: (load[d], d),
                )
                order.append(free_per_dev[dev].pop(0))
                load[dev] += 1
            return order

        def admit_free_slots(state: BatchState) -> BatchState:
            with rec.span("service.admit", it=it) as sp:
                n_admitted = 0
                for slot in admission_order():
                    req = pull()
                    if req is None:
                        break
                    state = engine.admit(
                        state, slot, req.theta, req.rel_tol, req.abs_tol
                    )
                    slot_req[slot] = req
                    slot_admitted[slot] = it
                    slot_wall[slot] = time.monotonic()
                    n_admitted += 1
                    bump("admissions")
                    rec.event(
                        "service.admission",
                        lane=slot // engine.slots_per_device,
                        req_id=req.req_id,
                        slot=slot,
                        it=it,
                    )
                sp["admitted"] = n_admitted
            return state

        def admission_tick(state: BatchState) -> BatchState:
            """One admission pass + the checkpoint cadence hanging off it.

            The snapshot is taken *after* the admissions so a resumed run
            continues from a tick boundary: the next host decision after
            restore is the next dispatch, exactly as in the original run.
            Mesh regrowth also hangs off the tick: a failed device that the
            injector reports healthy again rejoins here, before the
            admissions, so fresh admissions spread across the regrown mesh.
            """
            nonlocal engine, ticks
            probe = getattr(self.fault_injector, "healthy", None)
            if self._failed and probe is not None:
                restored = [gi for gi in sorted(self._failed) if probe(gi, it)]
                if restored:
                    self._failed.difference_update(restored)
                    target = self._healthy_mesh()
                    if len(target) > engine.n_devices:
                        with rec.span(
                            "service.mesh_regrow", it=it, devices=len(target)
                        ):
                            host = jax.tree.map(np.asarray, jax.device_get(state))
                            engine = self._rebuild_engine(target)
                            state = engine.place(host)
                        bump("mesh_regrows")
                        rec.event(
                            "service.mesh_regrow",
                            it=it,
                            devices=len(target),
                            restored=restored,
                        )
            state = admit_free_slots(state)
            ticks += 1
            if (
                self.checkpointer is not None
                and self.checkpoint_every > 0
                and ticks % self.checkpoint_every == 0
            ):
                meta = {
                    "it": it,
                    "ticks": ticks,
                    "stats": stats.as_dict(),
                    "pulled_ids": sorted(pulled_ids),
                    "slots": [
                        {
                            "slot": s,
                            "req": encode_request(slot_req[s]),
                            "admitted_at": int(slot_admitted[s]),
                        }
                        for s in range(B)
                        if slot_req[s] is not None
                    ],
                }
                with rec.span("service.checkpoint", it=it, ticks=ticks):
                    self.checkpointer.save(it, state, meta)
                bump("checkpoints")
            return state

        def apply_moves(rows: np.ndarray) -> None:
            """Replay one iteration's device-side migrations onto the host
            map.  Within a round sources (live slots) and destinations
            (previously free slots) are disjoint, so copy-then-clear is
            exact."""
            valid = [(int(s), int(d)) for s, d in rows if s >= 0]
            if not valid:
                return
            snapshot_req = list(slot_req)
            snapshot_adm = slot_admitted.copy()
            snapshot_wall = list(slot_wall)
            for src, dst in valid:
                assert snapshot_req[src] is not None, (src, dst)
                slot_req[dst] = snapshot_req[src]
                slot_admitted[dst] = snapshot_adm[src]
                slot_wall[dst] = snapshot_wall[src]
                slot_req[src] = None
                if rec.enabled:
                    rec.flow(
                        "service.migrate",
                        src // engine.slots_per_device,
                        dst // engine.slots_per_device,
                        req_id=snapshot_req[src].req_id,
                        src_slot=src,
                        dst_slot=dst,
                        it=it,
                    )
            bump("migrations", len(valid))

        def evacuate_and_shrink(state: BatchState, dev: int) -> BatchState:
            """Recover the failed device's slots and rebuild on the survivors.

            Evacuation ordering (DESIGN.md §6): snapshot-covered slots are
            rewound to the newest readable service snapshot (their replay is
            deterministic, so final values stay bit-identical); uncovered
            slots lose their progress and their requests re-enter the queue
            with ``attempts``/``retried_from``/``evacuated`` provenance.
            Surviving devices' slots are carried over untouched — their
            trajectories are placement-independent, so shrink cannot change
            their bits.
            """
            nonlocal engine
            if self._all_devices is None or engine.n_devices <= 1:
                raise DeviceLostError(
                    dev,
                    f"device {dev} lost permanently with no surviving "
                    "sub-mesh to evacuate to",
                )
            per_dev = engine.slots_per_device
            local = self._current_devs.index(dev)
            self._failed.add(dev)
            rec.event("service.device_lost", device=dev, it=it)
            target = self._healthy_mesh()
            new_per = B // len(target)
            with rec.span("service.evacuate", it=it, device=dev) as sp:
                # Host copy of the fleet state.  The fault fired at the
                # dispatch boundary (pre-dispatch hook / abandoned launch),
                # so the buffers were never donated into a completed
                # dispatch and remain readable.  A real device loss would
                # lose the failed shard's rows — exactly the rows rewritten
                # or released below; surviving rows are all that is trusted.
                host = jax.tree.map(np.array, jax.device_get(state))
                snap_state = snap_meta = None
                if self.checkpointer is not None:
                    try:
                        snap_state, snap_meta, _ = self.checkpointer.restore_host(
                            host
                        )
                    except FileNotFoundError:
                        pass
                snap_slots = {}
                if snap_meta is not None:
                    snap_state = jax.tree.map(np.asarray, snap_state)
                    snap_slots = {
                        int(e["slot"]): int(e["req"]["req_id"])
                        for e in snap_meta["slots"]
                    }
                recovered = readmitted = 0
                for s in range(local * per_dev, (local + 1) * per_dev):
                    req = slot_req[s]
                    if req is None:
                        continue
                    if snap_state is not None and snap_slots.get(s) == req.req_id:
                        # rewind the slot to the snapshot row-for-row
                        # (occupied/done flags included); the deterministic
                        # replay re-derives the lost refinement
                        jax.tree.map(lambda h, v: h.__setitem__(s, v[s]), host, snap_state)
                        evac_kind[req.req_id] = "snapshot"
                        slot_wall[s] = time.monotonic()  # wall SLO restarts
                        kind = "snapshot"
                        recovered += 1
                    else:
                        host.occupied[s] = False
                        host.done[s] = False
                        retry_queue.append(req)
                        evac_attempts[req.req_id] = evac_attempts.get(req.req_id, 0) + 1
                        evac_from[req.req_id] = "device_lost"
                        evac_kind[req.req_id] = "readmit"
                        slot_req[s] = None
                        kind = "readmit"
                        readmitted += 1
                    bump("evacuations")
                    if rec.enabled:
                        # lanes are original-mesh device indices: src is the
                        # failed device, dst the slot row's new owner
                        # "via", not "kind": attrs merge into the event
                        # envelope, whose own "kind" key is the event type
                        rec.flow(
                            "service.evacuate",
                            dev,
                            target[s // new_per],
                            req_id=req.req_id,
                            slot=s,
                            it=it,
                            via=kind,
                        )
                sp["recovered"] = recovered
                sp["readmitted"] = readmitted
            with rec.span(
                "service.mesh_shrink", it=it, devices=len(target), failed=dev
            ):
                engine = self._rebuild_engine(target)
                state = engine.place(host)
            bump("mesh_shrinks")
            rec.event(
                "service.mesh_shrink",
                it=it,
                devices=len(target),
                failed=sorted(self._failed),
            )
            return state

        # Dispatch-latency views (DESIGN.md §9): per-dispatch wall time and
        # the host-side queue wait between dispatches (admission, collection,
        # checkpointing, result consumption — everything the devices idle
        # through).  Recorded strictly at dispatch boundaries on the
        # recorder's clock, so recorder-off runs are bit-identical.
        last_dispatch_end: Optional[float] = None
        if not resume:
            # on resume the snapshot was taken at a tick boundary, right
            # after its admissions: the next host decision is the dispatch
            state = admission_tick(state)
        while any(r is not None for r in slot_req) or retry_queue:
            if not any(r is not None for r in slot_req):
                # an evacuation emptied the fleet with re-admissions
                # pending: refill before dispatching
                state = admission_tick(state)
                continue
            # A dispatch may not run past the next admit tick while an
            # admission may be pending (free slot + a queue not yet known to
            # be exhausted) — the tick is a host decision the device cannot
            # replay.  Whether the queue actually still holds a request is
            # only discovered AT the tick, preserving the unfused loop's
            # exact pull timing; once the iterator is exhausted, full-length
            # dispatches resume for the drain phase.
            max_steps = cfg.sync_every
            if (not exhausted or retry_queue) and any(r is None for r in slot_req):
                max_steps = min(max_steps, cfg.admit_every - it % cfg.admit_every)
            it0 = it

            def attempt_dispatch():
                # the injector hook fires first: an injected loss surfaces
                # before the engine consumes (donates) the state buffers,
                # so a retry or an evacuation reads intact state
                if self.fault_injector is not None:
                    self.fault_injector.pre_dispatch(it, tuple(self._current_devs))
                new_state, ms, executed, moved = engine.run(state, max_steps, it)
                ms, executed, moved = jax.device_get((ms, executed, moved))
                return new_state, ms, executed, moved

            # the first-ever dispatch traces + compiles the fused step, so
            # its span is the trace's "compile" lane entry
            evacuated = False
            if rec.enabled:
                t_dispatch0 = rec.clock()
                if last_dispatch_end is not None:
                    rec.observe(
                        "service.queue_wait_s",
                        t_dispatch0 - last_dispatch_end,
                        it=it,
                    )
            with rec.span(
                "service.dispatch" if self._warm else "service.compile",
                it=it,
                max_steps=max_steps,
            ) as sp:
                attempt = 0
                while True:
                    try:
                        state, ms, executed, moved = _call_with_timeout(
                            attempt_dispatch, self.dispatch_timeout_s
                        )
                        k = int(np.sum(executed))
                        break
                    except (DeviceLostError, DispatchTimeout) as err:
                        dev = self._attribute_fault(err, it)
                        rec.event(
                            "service.dispatch_fault",
                            it=it,
                            device=dev,
                            attempt=attempt,
                            error=type(err).__name__,
                        )
                        if attempt < self.max_dispatch_retries:
                            # transient until proven permanent: bounded
                            # retries with exponential backoff
                            attempt += 1
                            bump("dispatch_retries")
                            if self.retry_backoff_s > 0:
                                time.sleep(
                                    self.retry_backoff_s * 2 ** (attempt - 1)
                                )
                            continue
                        if dev is None:
                            raise  # unattributable: nothing to evacuate
                        state = evacuate_and_shrink(state, dev)
                        evacuated = True
                        k = 0
                        break
                sp["executed"] = k
            if evacuated:
                # no iteration executed: loop back and dispatch the same
                # ``it`` on the shrunken mesh (re-admissions wait for their
                # admit tick, exactly like any other queued request).  No
                # wall-time sample either — the next successful dispatch's
                # queue wait absorbs the whole recovery gap, which is the
                # honest account of where the time went.
                continue
            if rec.enabled:
                last_dispatch_end = rec.clock()
                rec.observe(
                    "service.dispatch_wall_s",
                    last_dispatch_end - t_dispatch0,
                    it=it0,
                )
            self._warm = True
            assert k >= 1, "fused dispatch executed no iterations"
            bump("dispatches")
            bump("iterations", k)
            if rec.enabled:
                # Per-device live-slot occupancy at every executed iteration
                # (the Fig. 4b input) — derived purely from the read-back
                # metrics, after the dispatch returned: nothing here can
                # perturb the device computation.
                occ = np.asarray(ms["occupied"][:k]).reshape(
                    k, engine.n_devices, engine.slots_per_device
                )
                n_live = occ.sum(axis=2)
                for t in range(k):
                    for dev in range(engine.n_devices):
                        rec.gauge(
                            "service.n_live",
                            int(n_live[t, dev]),
                            lane=dev,
                            it=it0 + t + 1,
                        )
                if "window" in ms:  # eval-window rung (cubature engine)
                    rec.gauge(
                        "service.window",
                        int(np.max(ms["window"][k - 1])),
                        it=it0 + k,
                    )
            for t in range(k - 1):
                it += 1
                apply_moves(moved[t])
            it += 1
            done = ms["done"][k - 1]
            occupied = ms["occupied"][k - 1]
            finished = [
                (slot_req[s].req_id, s)
                for s in range(B)
                if done[s] and occupied[s] and slot_req[s] is not None
            ]
            # req_id order: deterministic across device counts (collection
            # within one iteration has no inherent slot order anyway).
            # Results are built inside the collect span and yielded after
            # it closes — a span held open across a generator yield would
            # measure the consumer, not the collection.
            collected: list[QuadResult] = []
            if finished:
                with rec.span("service.collect", it=it, n=len(finished)):
                    for req_id, slot in sorted(finished):
                        status = engine.status_of(
                            bool(ms["converged"][k - 1][slot]),
                            int(ms["n_active"][k - 1][slot]),
                            int(ms["it"][k - 1][slot]),
                            bool(ms["overflowed"][k - 1][slot]),
                            bool(ms["nonfinite"][k - 1][slot]),
                        )
                        bump("collections")
                        if status == "nonfinite":
                            bump("quarantines")
                        rec.event(
                            "service.collected",
                            lane=slot // engine.slots_per_device,
                            req_id=req_id,
                            slot=slot,
                            status=status,
                            it=it,
                        )
                        collected.append(
                            QuadResult(
                                req_id=req_id,
                                integral=float(ms["integral"][k - 1][slot]),
                                error=float(ms["error"][k - 1][slot]),
                                status=status,
                                iterations=int(ms["it"][k - 1][slot]),
                                n_evals=float(ms["n_evals"][k - 1][slot]),
                                admitted_at=int(slot_admitted[slot]),
                                finished_at=it,
                                backend=engine.backend,
                                attempts=1 + evac_attempts.pop(req_id, 0),
                                retried_from=evac_from.pop(req_id, None),
                                evacuated=evac_kind.pop(req_id, None),
                            )
                        )
            for res in collected:
                yield res
            # migrations of the final executed iteration happened *after* its
            # metrics snapshot (and done slots never migrate), so the map
            # update follows collection
            apply_moves(moved[k - 1])
            for _, slot in finished:
                state = engine.release(state, slot)
                slot_req[slot] = None
            # Deadline sweep: SLOs are enforced here, at the dispatch
            # boundary (the host cannot observe a slot mid-dispatch).  The
            # evicted slot's row-(k-1) metrics are its best-effort partial
            # estimate; releasing it only clears this slot's masks, so the
            # other slots' trajectories are untouched bit-for-bit.
            now = time.monotonic()
            for slot in range(B):
                req = slot_req[slot]
                if req is None or (req.deadline_s is None and req.max_evals is None):
                    continue
                over_wall = (
                    req.deadline_s is not None
                    and now - slot_wall[slot] > req.deadline_s
                )
                over_evals = (
                    req.max_evals is not None
                    and float(ms["n_evals"][k - 1][slot]) > req.max_evals
                )
                if not (over_wall or over_evals):
                    continue
                bump("deadlines")
                rec.event(
                    "service.deadline",
                    lane=slot // engine.slots_per_device,
                    req_id=req.req_id,
                    slot=slot,
                    it=it,
                    over_wall=over_wall,
                    over_evals=over_evals,
                )
                yield QuadResult(
                    req_id=req.req_id,
                    integral=float(ms["integral"][k - 1][slot]),
                    error=float(ms["error"][k - 1][slot]),
                    status="deadline",
                    iterations=int(ms["it"][k - 1][slot]),
                    n_evals=float(ms["n_evals"][k - 1][slot]),
                    admitted_at=int(slot_admitted[slot]),
                    finished_at=it,
                    backend=engine.backend,
                    attempts=1 + evac_attempts.pop(req.req_id, 0),
                    retried_from=evac_from.pop(req.req_id, None),
                    evacuated=evac_kind.pop(req.req_id, None),
                )
                state = engine.release(state, slot)
                slot_req[slot] = None
            # Admit on the configured cadence — but never let the fleet go
            # idle with work still queued: if every slot just drained we
            # admit immediately rather than spinning (or exiting) until the
            # next admit tick.
            if it % cfg.admit_every == 0 or all(r is None for r in slot_req):
                state = admission_tick(state)
            if self.on_tick is not None:
                replacement = self.on_tick(it, state, list(slot_req))
                if replacement is not None:
                    state = replacement
        # drain: nothing in flight, so nothing may remain unadmitted
        leftover = pull()
        if leftover is not None:  # pragma: no cover - invariant guard
            raise RuntimeError(
                f"scheduler exited with queued requests (req_id={leftover.req_id})"
            )
        rec.event("service.drain", it=it, **stats.as_dict())
        rec.flush()
