"""Continuous-batching scheduler for the batch quadrature engine.

The host-side loop that turns the fixed-shape :class:`BatchEngine` into a
service: a FIFO request queue feeds ``cfg.batch_slots`` slots; every
``cfg.admit_every`` iterations freed slots are refilled from the queue
(mid-flight — the other slots keep refining through the same compiled step),
and finished slots are collected and yielded as :class:`QuadResult`\\ s as
soon as their ``done`` flag flips, in convergence order rather than
submission order.

The engine is driven through its fused :meth:`~BatchEngine.run` protocol:
up to ``cfg.sync_every`` iterations execute per dispatch and the dispatch
exits early — from an on-device psum of per-slot done masks — the moment any
slot finishes, so the host observes every collection at its exact iteration.
The scheduler additionally caps a dispatch so it cannot run past the next
``admit_every`` tick while an admission is pending.  Together these make the
fused loop replay the unfused per-iteration loop decision-for-decision:
results (including ``admitted_at`` / ``finished_at``) are bit-identical at
any ``sync_every`` and any device count.

On a sharded engine the scheduler is also mesh-aware: admissions target the
device that owns the freed slot (free slots are filled on the least-loaded
device first, so fresh problems spread across the mesh), and the migration
records the engine emits when its cyclic rebalancer moves a problem between
devices are replayed onto the host's slot -> request map in iteration order.

Termination taxonomy per request (mirrors ``AdaptiveResult.status``):

- ``converged`` — error estimate under the request's budget;
- ``capacity`` — the slot's region store saturated (``overflowed``) and
  stayed unconverged for ``cfg.evict_patience`` further iterations: the
  engine freezes it and the scheduler *evicts* it with its best-effort
  estimate so the slot can serve the rest of the queue instead of grinding
  a hopeless problem (transient saturation that converges within the grace
  period keeps exact parity with the serial driver);
- ``no_active`` / ``max_iters`` — degenerate population / iteration cap;
- ``nonfinite`` — the slot produced NaN/Inf estimates; the engine quarantined
  the offending regions (zeroed their contributions, deactivated them) the
  same iteration, so the rest of the fleet's psum'd reductions never see the
  poison, and the scheduler collects the slot with its best-effort estimate;
- ``deadline`` — the request's SLO (``deadline_s`` wall clock and/or
  ``max_evals`` evaluation budget) expired at a dispatch boundary: the
  scheduler evicts the slot with its best-effort partial estimate instead of
  letting one slow problem hold a slot indefinitely.

Graceful degradation on top of this taxonomy (fallback re-routing of
``capacity``/``nonfinite`` evictions to the VEGAS pool, looser-tolerance
retries) lives in :mod:`repro.service.routing`; service-level
checkpoint/resume in :mod:`repro.service.checkpoint`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Union

import jax
import numpy as np

from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.batch_engine import BatchEngine, BatchState
from repro.telemetry import NULL, ServiceStats


def make_engine(
    cfg: QuadratureConfig,
    family: Union[ParamIntegrand, str, None] = None,
    mesh=None,
    devices=None,
    recorder=NULL,
):
    """Engine for ``cfg``'s resolved backend.

    The service fronts two engine pools behind one scheduler protocol
    (``init``/``admit``/``release``/fused ``run`` + ``status_of``): the
    deterministic cubature :class:`BatchEngine` and the Monte Carlo
    :class:`~repro.mc.engine.VegasBatchEngine` — ``backend="auto"`` picks by
    the problem dimension, so high-d fleets are admitted through MC instead
    of being rejected by region-store explosion.
    """
    if cfg.resolved_backend() == "vegas":
        from repro.mc.engine import VegasBatchEngine

        return VegasBatchEngine(
            cfg, family, mesh=mesh, devices=devices, recorder=recorder
        )
    return BatchEngine(cfg, family, mesh=mesh, devices=devices, recorder=recorder)


@dataclasses.dataclass(frozen=True)
class QuadRequest:
    """One integration problem: a theta of the engine's family + tolerances.

    ``deadline_s`` / ``max_evals`` are best-effort SLOs, checked at dispatch
    boundaries (the host only observes slot metrics between fused launches):
    once either budget is exhausted the slot is evicted with its current
    partial estimate and status ``deadline`` instead of holding the slot.
    ``max_evals`` is deterministic (counted in integrand evaluations);
    ``deadline_s`` is wall clock measured from admission.
    """

    req_id: int
    theta: Any  # pytree matching the family's theta_fields, leaves (d,)
    rel_tol: Optional[float] = None  # None -> cfg default
    abs_tol: Optional[float] = None
    deadline_s: Optional[float] = None  # wall-clock budget from admission
    max_evals: Optional[float] = None  # integrand-evaluation budget


@dataclasses.dataclass(frozen=True)
class QuadResult:
    """Terminal state of one request (statuses as in AdaptiveResult).

    ``backend``/``attempts``/``retried_from`` record attempt provenance:
    which engine pool produced this estimate, how many admissions the
    request consumed in total, and — for re-routed/retried requests — the
    terminal status of the attempt that triggered the re-route (see
    :class:`repro.service.routing.GracefulScheduler`).
    """

    req_id: int
    integral: float
    error: float
    status: str  # converged | capacity | no_active | max_iters | nonfinite | deadline
    iterations: int  # per-slot adaptive iterations spent on this problem
    n_evals: float  # integrand evaluations spent on this problem
    admitted_at: int  # scheduler iteration at which the slot was filled
    finished_at: int  # scheduler iteration at which done flipped on
    backend: str = "cubature"  # engine pool that produced this estimate
    attempts: int = 1  # admissions consumed (1 = first attempt)
    retried_from: Optional[str] = None  # prior attempt's terminal status

    def summary(self) -> str:
        via = f" via={self.backend}" if self.attempts > 1 else ""
        return (
            f"req={self.req_id} I={self.integral:.15e} eps={self.error:.3e} "
            f"[{self.status}] iters={self.iterations} evals={self.n_evals:.3g}"
            f"{via}"
        )


def encode_request(req: QuadRequest) -> dict:
    """JSON-able form of a request (theta leaves as float64 lists).

    ``json`` serialises float64 via ``repr``, which round-trips bit-exactly,
    so a decode of an encode reconstructs the identical problem — the
    service checkpoint's resume-parity argument rests on this.
    """
    return {
        "req_id": int(req.req_id),
        "theta": jax.tree.map(
            lambda x: np.asarray(x, np.float64).tolist(), req.theta
        ),
        "rel_tol": None if req.rel_tol is None else float(req.rel_tol),
        "abs_tol": None if req.abs_tol is None else float(req.abs_tol),
        "deadline_s": None if req.deadline_s is None else float(req.deadline_s),
        "max_evals": None if req.max_evals is None else float(req.max_evals),
    }


def decode_request(obj: dict, theta_template) -> QuadRequest:
    """Inverse of :func:`encode_request`.

    ``theta_template`` (the engine's) supplies the pytree structure so the
    stored nested lists land as leaves of the right shape rather than being
    re-flattened as pytrees themselves.
    """
    theta = jax.tree.map(
        lambda t, v: np.asarray(v, np.float64).reshape(np.shape(t)),
        theta_template,
        obj["theta"],
    )
    return QuadRequest(
        req_id=int(obj["req_id"]),
        theta=theta,
        rel_tol=obj.get("rel_tol"),
        abs_tol=obj.get("abs_tol"),
        deadline_s=obj.get("deadline_s"),
        max_evals=obj.get("max_evals"),
    )


class BatchScheduler:
    """Drives a :class:`BatchEngine` over an arbitrary stream of requests.

    After :meth:`serve` completes, :attr:`last_stats` is a dict view of the
    run's :class:`~repro.telemetry.ServiceStats` — ``iterations`` (fleet
    iterations), ``dispatches`` (fused engine launches), ``admissions``,
    ``collections``, ``migrations`` (problems moved between devices by the
    cyclic rebalancer), ``quarantines`` (slots collected with a
    ``nonfinite`` status), ``deadlines`` (slots evicted on an expired SLO)
    and ``checkpoints``.

    ``recorder`` (a :class:`repro.telemetry.Recorder`; default the no-op
    :data:`~repro.telemetry.NULL`) receives the structured event stream:
    spans around compile/dispatch/admit/collect/checkpoint, per-device
    ``service.n_live`` occupancy gauges at every executed iteration, and
    flow events for slot migrations.  Everything is recorded host-side at
    dispatch boundaries, so telemetry on/off cannot change any result bit
    (see DESIGN.md §8).

    ``checkpointer`` (a :class:`repro.service.checkpoint.ServiceCheckpointer`)
    snapshots the stacked engine state + the slot -> request map every
    ``checkpoint_every`` admission ticks; ``serve(resume=True)`` restores the
    latest snapshot and replays from it — bit-identically for slots the
    crash did not touch.  ``on_tick(it, state, slot_req)`` is a host hook
    called at every dispatch boundary (fault injection, external monitoring);
    it may return a replacement state pytree or ``None``.
    """

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        engine: Optional[BatchEngine] = None,
        mesh=None,
        devices=None,
        checkpointer=None,
        checkpoint_every: int = 0,
        on_tick: Optional[Callable] = None,
        recorder=NULL,
    ):
        self.recorder = recorder
        if engine is not None:
            if mesh is not None or devices is not None:
                raise ValueError(
                    "pass mesh/devices to the BatchEngine, not alongside an "
                    "explicit engine: the engine's mesh is fixed at "
                    "construction and a conflicting argument here would be "
                    "silently ignored"
                )
            self.engine = engine
        else:
            self.engine = make_engine(
                cfg, family, mesh=mesh, devices=devices, recorder=recorder
            )
        self.cfg = self.engine.cfg
        if checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if checkpoint_every and checkpointer is None:
            raise ValueError("checkpoint_every > 0 requires a checkpointer")
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        self.on_tick = on_tick
        self._stats = ServiceStats()
        self._warm = False  # first-ever dispatch traces + compiles the step

    @property
    def last_stats(self) -> dict:
        """Dict view of the latest run's :class:`ServiceStats` (compat)."""
        return self._stats.as_dict()

    def serve(
        self, requests: Iterable[QuadRequest], resume: bool = False
    ) -> Iterator[QuadResult]:
        """Run the fleet to completion, yielding results as slots converge.

        ``requests`` may be any iterable (including a generator — it is only
        pulled from when a slot is free, so an unbounded stream backpressures
        naturally).  Every request yields exactly one result.

        With ``resume=True`` the latest service checkpoint is restored first:
        in-flight slots resume mid-refinement, requests the crashed run had
        already pulled are skipped from ``requests`` (the caller re-supplies
        the same stream), and requests that finished *after* the restored
        snapshot are served again — deterministically, so the duplicates are
        bit-identical to the results the crashed run already yielded.
        """
        engine = self.engine
        cfg = self.cfg
        B = engine.n_slots
        per_dev = engine.slots_per_device
        pending = iter(requests)
        exhausted = False  # the iterator signalled StopIteration
        slot_req: list[Optional[QuadRequest]] = [None] * B
        slot_admitted = np.zeros(B, np.int64)
        slot_wall = [0.0] * B  # admission wall clock, for deadline_s
        pulled_ids: set[int] = set()
        skip_ids: set[int] = set()
        rec = self.recorder
        stats = ServiceStats()
        self._stats = stats

        def bump(counter: str, n: int = 1) -> None:
            # one typed schema + one event stream: every host-loop counter
            # bump lands in ServiceStats AND (when enabled) the recorder
            stats.add(counter, n)
            rec.count(f"service.{counter}", n)

        state = engine.init()
        it = 0
        ticks = 0  # admission passes completed (checkpoint cadence unit)
        rec.event(
            "service.start",
            backend=engine.backend,
            slots=B,
            devices=engine.n_devices,
            sync_every=cfg.sync_every,
            admit_every=cfg.admit_every,
            resume=resume,
        )

        if resume:
            if self.checkpointer is None:
                raise ValueError("resume=True requires a checkpointer")
            state, meta = self.checkpointer.restore(engine)
            it = int(meta["it"])
            ticks = int(meta["ticks"])
            stats.merge(ServiceStats.from_dict(meta["stats"]))
            pulled_ids = set(meta["pulled_ids"])
            skip_ids = set(pulled_ids)
            for entry in meta["slots"]:
                slot = int(entry["slot"])
                slot_req[slot] = decode_request(entry["req"], engine.theta_template)
                slot_admitted[slot] = int(entry["admitted_at"])
                slot_wall[slot] = time.monotonic()  # wall deadlines restart

        def pull() -> Optional[QuadRequest]:
            # Requests are pulled ONLY here, from admission passes — never
            # speculatively — so a generator that derives its next request
            # from results yielded so far sees exactly the per-iteration
            # loop's pull points, and an unbounded stream backpressures on
            # slot availability.  On resume, requests the crashed run had
            # already pulled are skipped so the replayed stream lines up
            # with the restored slot map.
            nonlocal exhausted
            if exhausted:
                return None
            req = next(pending, None)
            while req is not None and req.req_id in skip_ids:
                req = next(pending, None)
            if req is None:
                exhausted = True
            else:
                pulled_ids.add(req.req_id)
            return req

        def admission_order() -> list[int]:
            """Free slots, least-loaded device first (plain slot order on one
            device, which is exactly the legacy single-device fill order)."""
            free = [s for s in range(B) if slot_req[s] is None]
            if engine.n_devices == 1:
                return free
            load = [0] * engine.n_devices
            for s in range(B):
                if slot_req[s] is not None:
                    load[s // per_dev] += 1
            # admitting onto a device raises its load for the next pick, so
            # a burst of admissions round-robins across the drained devices
            order: list[int] = []
            free_per_dev = [[s for s in free if s // per_dev == d] for d in range(engine.n_devices)]
            for _ in free:
                dev = min(
                    (d for d in range(engine.n_devices) if free_per_dev[d]),
                    key=lambda d: (load[d], d),
                )
                order.append(free_per_dev[dev].pop(0))
                load[dev] += 1
            return order

        def admit_free_slots(state: BatchState) -> BatchState:
            with rec.span("service.admit", it=it) as sp:
                n_admitted = 0
                for slot in admission_order():
                    req = pull()
                    if req is None:
                        break
                    state = engine.admit(
                        state, slot, req.theta, req.rel_tol, req.abs_tol
                    )
                    slot_req[slot] = req
                    slot_admitted[slot] = it
                    slot_wall[slot] = time.monotonic()
                    n_admitted += 1
                    bump("admissions")
                    rec.event(
                        "service.admission",
                        lane=slot // per_dev,
                        req_id=req.req_id,
                        slot=slot,
                        it=it,
                    )
                sp["admitted"] = n_admitted
            return state

        def admission_tick(state: BatchState) -> BatchState:
            """One admission pass + the checkpoint cadence hanging off it.

            The snapshot is taken *after* the admissions so a resumed run
            continues from a tick boundary: the next host decision after
            restore is the next dispatch, exactly as in the original run.
            """
            nonlocal ticks
            state = admit_free_slots(state)
            ticks += 1
            if (
                self.checkpointer is not None
                and self.checkpoint_every > 0
                and ticks % self.checkpoint_every == 0
            ):
                meta = {
                    "it": it,
                    "ticks": ticks,
                    "stats": stats.as_dict(),
                    "pulled_ids": sorted(pulled_ids),
                    "slots": [
                        {
                            "slot": s,
                            "req": encode_request(slot_req[s]),
                            "admitted_at": int(slot_admitted[s]),
                        }
                        for s in range(B)
                        if slot_req[s] is not None
                    ],
                }
                with rec.span("service.checkpoint", it=it, ticks=ticks):
                    self.checkpointer.save(it, state, meta)
                bump("checkpoints")
            return state

        def apply_moves(rows: np.ndarray) -> None:
            """Replay one iteration's device-side migrations onto the host
            map.  Within a round sources (live slots) and destinations
            (previously free slots) are disjoint, so copy-then-clear is
            exact."""
            valid = [(int(s), int(d)) for s, d in rows if s >= 0]
            if not valid:
                return
            snapshot_req = list(slot_req)
            snapshot_adm = slot_admitted.copy()
            snapshot_wall = list(slot_wall)
            for src, dst in valid:
                assert snapshot_req[src] is not None, (src, dst)
                slot_req[dst] = snapshot_req[src]
                slot_admitted[dst] = snapshot_adm[src]
                slot_wall[dst] = snapshot_wall[src]
                slot_req[src] = None
                if rec.enabled:
                    rec.flow(
                        "service.migrate",
                        src // per_dev,
                        dst // per_dev,
                        req_id=snapshot_req[src].req_id,
                        src_slot=src,
                        dst_slot=dst,
                        it=it,
                    )
            bump("migrations", len(valid))

        if not resume:
            # on resume the snapshot was taken at a tick boundary, right
            # after its admissions: the next host decision is the dispatch
            state = admission_tick(state)
        while any(r is not None for r in slot_req):
            # A dispatch may not run past the next admit tick while an
            # admission may be pending (free slot + a queue not yet known to
            # be exhausted) — the tick is a host decision the device cannot
            # replay.  Whether the queue actually still holds a request is
            # only discovered AT the tick, preserving the unfused loop's
            # exact pull timing; once the iterator is exhausted, full-length
            # dispatches resume for the drain phase.
            max_steps = cfg.sync_every
            if not exhausted and any(r is None for r in slot_req):
                max_steps = min(max_steps, cfg.admit_every - it % cfg.admit_every)
            it0 = it
            # the first-ever dispatch traces + compiles the fused step, so
            # its span is the trace's "compile" lane entry
            with rec.span(
                "service.dispatch" if self._warm else "service.compile",
                it=it,
                max_steps=max_steps,
            ) as sp:
                state, ms, executed, moved = engine.run(state, max_steps, it)
                ms, executed, moved = jax.device_get((ms, executed, moved))
                k = int(np.sum(executed))
                sp["executed"] = k
            self._warm = True
            assert k >= 1, "fused dispatch executed no iterations"
            bump("dispatches")
            bump("iterations", k)
            if rec.enabled:
                # Per-device live-slot occupancy at every executed iteration
                # (the Fig. 4b input) — derived purely from the read-back
                # metrics, after the dispatch returned: nothing here can
                # perturb the device computation.
                occ = np.asarray(ms["occupied"][:k]).reshape(
                    k, engine.n_devices, per_dev
                )
                n_live = occ.sum(axis=2)
                for t in range(k):
                    for dev in range(engine.n_devices):
                        rec.gauge(
                            "service.n_live",
                            int(n_live[t, dev]),
                            lane=dev,
                            it=it0 + t + 1,
                        )
                if "window" in ms:  # eval-window rung (cubature engine)
                    rec.gauge(
                        "service.window",
                        int(np.max(ms["window"][k - 1])),
                        it=it0 + k,
                    )
            for t in range(k - 1):
                it += 1
                apply_moves(moved[t])
            it += 1
            done = ms["done"][k - 1]
            occupied = ms["occupied"][k - 1]
            finished = [
                (slot_req[s].req_id, s)
                for s in range(B)
                if done[s] and occupied[s] and slot_req[s] is not None
            ]
            # req_id order: deterministic across device counts (collection
            # within one iteration has no inherent slot order anyway).
            # Results are built inside the collect span and yielded after
            # it closes — a span held open across a generator yield would
            # measure the consumer, not the collection.
            collected: list[QuadResult] = []
            if finished:
                with rec.span("service.collect", it=it, n=len(finished)):
                    for req_id, slot in sorted(finished):
                        status = engine.status_of(
                            bool(ms["converged"][k - 1][slot]),
                            int(ms["n_active"][k - 1][slot]),
                            int(ms["it"][k - 1][slot]),
                            bool(ms["overflowed"][k - 1][slot]),
                            bool(ms["nonfinite"][k - 1][slot]),
                        )
                        bump("collections")
                        if status == "nonfinite":
                            bump("quarantines")
                        rec.event(
                            "service.collected",
                            lane=slot // per_dev,
                            req_id=req_id,
                            slot=slot,
                            status=status,
                            it=it,
                        )
                        collected.append(
                            QuadResult(
                                req_id=req_id,
                                integral=float(ms["integral"][k - 1][slot]),
                                error=float(ms["error"][k - 1][slot]),
                                status=status,
                                iterations=int(ms["it"][k - 1][slot]),
                                n_evals=float(ms["n_evals"][k - 1][slot]),
                                admitted_at=int(slot_admitted[slot]),
                                finished_at=it,
                                backend=engine.backend,
                            )
                        )
            for res in collected:
                yield res
            # migrations of the final executed iteration happened *after* its
            # metrics snapshot (and done slots never migrate), so the map
            # update follows collection
            apply_moves(moved[k - 1])
            for _, slot in finished:
                state = engine.release(state, slot)
                slot_req[slot] = None
            # Deadline sweep: SLOs are enforced here, at the dispatch
            # boundary (the host cannot observe a slot mid-dispatch).  The
            # evicted slot's row-(k-1) metrics are its best-effort partial
            # estimate; releasing it only clears this slot's masks, so the
            # other slots' trajectories are untouched bit-for-bit.
            now = time.monotonic()
            for slot in range(B):
                req = slot_req[slot]
                if req is None or (req.deadline_s is None and req.max_evals is None):
                    continue
                over_wall = (
                    req.deadline_s is not None
                    and now - slot_wall[slot] > req.deadline_s
                )
                over_evals = (
                    req.max_evals is not None
                    and float(ms["n_evals"][k - 1][slot]) > req.max_evals
                )
                if not (over_wall or over_evals):
                    continue
                bump("deadlines")
                rec.event(
                    "service.deadline",
                    lane=slot // per_dev,
                    req_id=req.req_id,
                    slot=slot,
                    it=it,
                    over_wall=over_wall,
                    over_evals=over_evals,
                )
                yield QuadResult(
                    req_id=req.req_id,
                    integral=float(ms["integral"][k - 1][slot]),
                    error=float(ms["error"][k - 1][slot]),
                    status="deadline",
                    iterations=int(ms["it"][k - 1][slot]),
                    n_evals=float(ms["n_evals"][k - 1][slot]),
                    admitted_at=int(slot_admitted[slot]),
                    finished_at=it,
                    backend=engine.backend,
                )
                state = engine.release(state, slot)
                slot_req[slot] = None
            # Admit on the configured cadence — but never let the fleet go
            # idle with work still queued: if every slot just drained we
            # admit immediately rather than spinning (or exiting) until the
            # next admit tick.
            if it % cfg.admit_every == 0 or all(r is None for r in slot_req):
                state = admission_tick(state)
            if self.on_tick is not None:
                replacement = self.on_tick(it, state, list(slot_req))
                if replacement is not None:
                    state = replacement
        # drain: nothing in flight, so nothing may remain unadmitted
        leftover = pull()
        if leftover is not None:  # pragma: no cover - invariant guard
            raise RuntimeError(
                f"scheduler exited with queued requests (req_id={leftover.req_id})"
            )
        rec.event("service.drain", it=it, **stats.as_dict())
        rec.flush()
