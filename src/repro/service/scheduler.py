"""Continuous-batching scheduler for the batch quadrature engine.

The host-side loop that turns the fixed-shape :class:`BatchEngine` into a
service: a FIFO request queue feeds ``cfg.batch_slots`` slots; every
``cfg.admit_every`` iterations freed slots are refilled from the queue
(mid-flight — the other slots keep refining through the same compiled step),
and finished slots are collected and yielded as :class:`QuadResult`\\ s as
soon as their ``done`` flag flips, in convergence order rather than
submission order.

Termination taxonomy per request (mirrors ``AdaptiveResult.status``):

- ``converged`` — error estimate under the request's budget;
- ``capacity`` — the slot's region store saturated (``overflowed``) and
  stayed unconverged for ``cfg.evict_patience`` further iterations: the
  engine freezes it and the scheduler *evicts* it with its best-effort
  estimate so the slot can serve the rest of the queue instead of grinding
  a hopeless problem (transient saturation that converges within the grace
  period keeps exact parity with the serial driver);
- ``no_active`` / ``max_iters`` — degenerate population / iteration cap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Iterator, Optional, Union

import numpy as np

from repro.core.adaptive import result_status
from repro.core.config import QuadratureConfig
from repro.core.integrands import ParamIntegrand
from repro.service.batch_engine import BatchEngine, BatchState


@dataclasses.dataclass(frozen=True)
class QuadRequest:
    """One integration problem: a theta of the engine's family + tolerances."""

    req_id: int
    theta: Any  # pytree matching the family's theta_fields, leaves (d,)
    rel_tol: Optional[float] = None  # None -> cfg default
    abs_tol: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class QuadResult:
    """Terminal state of one request (statuses as in AdaptiveResult)."""

    req_id: int
    integral: float
    error: float
    status: str  # converged | capacity | no_active | max_iters
    iterations: int  # per-slot adaptive iterations spent on this problem
    n_evals: float  # integrand evaluations spent on this problem
    admitted_at: int  # scheduler iteration at which the slot was filled
    finished_at: int  # scheduler iteration at which done flipped on

    def summary(self) -> str:
        return (
            f"req={self.req_id} I={self.integral:.15e} eps={self.error:.3e} "
            f"[{self.status}] iters={self.iterations} evals={self.n_evals:.3g}"
        )


class BatchScheduler:
    """Drives a :class:`BatchEngine` over an arbitrary stream of requests."""

    def __init__(
        self,
        cfg: QuadratureConfig,
        family: Union[ParamIntegrand, str, None] = None,
        engine: Optional[BatchEngine] = None,
    ):
        self.engine = engine if engine is not None else BatchEngine(cfg, family)
        self.cfg = self.engine.cfg

    def serve(self, requests: Iterable[QuadRequest]) -> Iterator[QuadResult]:
        """Run the fleet to completion, yielding results as slots converge.

        ``requests`` may be any iterable (including a generator — it is only
        pulled from when a slot is free, so an unbounded stream backpressures
        naturally).  Every request yields exactly one result.
        """
        engine = self.engine
        B = engine.n_slots
        pending = iter(requests)
        slot_req: list[Optional[QuadRequest]] = [None] * B
        slot_admitted = np.zeros(B, np.int64)
        state = engine.init()
        it = 0

        def pull() -> Optional[QuadRequest]:
            return next(pending, None)

        def admit_free_slots(state: BatchState) -> BatchState:
            for slot in range(B):
                if slot_req[slot] is not None:
                    continue
                req = pull()
                if req is None:
                    break
                state = engine.admit(
                    state, slot, req.theta, req.rel_tol, req.abs_tol
                )
                slot_req[slot] = req
                slot_admitted[slot] = it
            return state

        state = admit_free_slots(state)
        while any(r is not None for r in slot_req):
            state, metrics = engine.step(state)
            it += 1
            done = np.asarray(metrics["done"])
            occupied = np.asarray(metrics["occupied"])
            if np.any(done & occupied):
                metrics = {k: np.asarray(v) for k, v in metrics.items()}
                for slot in range(B):
                    if not (done[slot] and occupied[slot]):
                        continue
                    req = slot_req[slot]
                    yield QuadResult(
                        req_id=req.req_id,
                        integral=float(metrics["integral"][slot]),
                        error=float(metrics["error"][slot]),
                        status=result_status(
                            bool(metrics["converged"][slot]),
                            int(metrics["n_active"][slot]),
                            int(metrics["it"][slot]),
                            self.cfg,
                            bool(metrics["overflowed"][slot]),
                        ),
                        iterations=int(metrics["it"][slot]),
                        n_evals=float(metrics["n_evals"][slot]),
                        admitted_at=int(slot_admitted[slot]),
                        finished_at=it,
                    )
                    state = engine.release(state, slot)
                    slot_req[slot] = None
            # Admit on the configured cadence — but never let the fleet go
            # idle with work still queued: if every slot just drained we
            # admit immediately rather than spinning (or exiting) until the
            # next admit tick.
            if it % self.cfg.admit_every == 0 or all(
                r is None for r in slot_req
            ):
                state = admit_free_slots(state)
        # drain: nothing in flight, so nothing may remain unadmitted
        leftover = pull()
        if leftover is not None:  # pragma: no cover - invariant guard
            raise RuntimeError(
                f"scheduler exited with queued requests (req_id={leftover.req_id})"
            )
