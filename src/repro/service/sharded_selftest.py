"""Self-contained sharded-service parity self-test (subprocess-run by tests).

Must be launched as ``python -m repro.service.sharded_selftest [n_devices]``
— sets XLA_FLAGS before importing jax, then runs the batch quadrature
service over meshes of 1, 2, ..., n_devices virtual devices and asserts the
acceptance criterion of the sharded service: every :class:`QuadResult`
(integral, error, status, iterations, n_evals, admitted_at, finished_at) is
bit-identical at every device count, for every terminal status —
``converged``, ``max_iters`` and ``evicted`` (status ``capacity``) — with
mid-flight admission exercised, with the cyclic problem rebalancer both
on and off (a drain-heavy case asserts it actually migrates), and with the
windowed advance both on (the default) and off — so the sharded service
provably replays the same trajectories when the whole iteration is
windowed.  With a recorder attached the drain-heavy case must additionally
keep bit-parity (telemetry never perturbs trajectories), emit at least one
migration flow pair into a structurally valid Chrome trace, and produce an
idle-fraction timeline that matches the fig-4b formula recomputed by hand.
Human progress goes through ``logging`` (``-q``/``-v``); the machine-readable
``RESULT_JSON:`` line on stdout stays byte-identical for CI consumers.
Prints one JSON blob on the last line.
"""

import argparse
import json
import os
import tempfile

from repro.telemetry.logutil import add_verbosity_flags, setup_logging


def _tuples(results):
    return [
        (
            r.req_id,
            r.integral.hex() if hasattr(r.integral, "hex") else r.integral,
            r.error.hex() if hasattr(r.error, "hex") else r.error,
            r.status,
            r.iterations,
            r.n_evals,
            r.admitted_at,
            r.finished_at,
        )
        for r in sorted(results, key=lambda r: r.req_id)
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("n_devices", nargs="?", type=int, default=4)
    add_verbosity_flags(ap)
    args = ap.parse_args()
    log = setup_logging(quiet=args.quiet, verbose=args.verbose)
    n_dev = args.n_devices
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import QuadratureConfig
    from repro.core.integrands import get_param
    from repro.service import BatchScheduler, QuadRequest

    assert len(jax.devices()) == n_dev, jax.devices()
    # batch_slots=8 below divides every mesh size up to 8
    counts = [c for c in (1, 2, 4, 8) if c <= n_dev]
    family = get_param("genz_gaussian")
    d = 2

    def requests(n, seed, rel_tols=None):
        rng = np.random.default_rng(seed)
        return [
            QuadRequest(
                req_id=i,
                theta=family.sample_theta(d, rng),
                rel_tol=None if rel_tols is None else rel_tols[i],
            )
            for i in range(n)
        ]

    base = dict(d=d, integrand="genz_gaussian", sync_every=4)
    cases = {
        # more requests than slots: mid-flight admission on every mesh
        "converged_midflight": (
            QuadratureConfig(
                **base, rel_tol=1e-5, capacity=1 << 9, batch_slots=8, max_iters=80
            ),
            lambda: requests(14, seed=0),
        ),
        # undersized store + hopeless tolerance: the hard slot overflows,
        # grinds through evict_patience, and is evicted with status
        # "capacity" while easy requests keep flowing through
        "evicted": (
            QuadratureConfig(
                **base, rel_tol=1e-4, capacity=1 << 7, batch_slots=8, max_iters=80
            ),
            lambda: requests(12, seed=3, rel_tols=[1e-9] + [1e-4] * 11),
        ),
        # iteration cap: frozen after exactly max_iters eval sweeps
        "max_iters": (
            QuadratureConfig(
                **base, rel_tol=1e-14, capacity=1 << 9, batch_slots=8, max_iters=6
            ),
            lambda: requests(8, seed=7),
        ),
        # drain-heavy fleet: the loose-tolerance problems land one per
        # device (round-robin admission), finish early, and their devices
        # pull queued work from ring partners — the migration case
        # round-robin admission lands requests k, k+n_dev, ... on device k,
        # so parity-striped tolerances drain half the devices completely
        # (2 slots/device even on the 8-ring) while the other half stay busy
        "rebalanced": (
            QuadratureConfig(
                **base, rel_tol=1e-8, capacity=1 << 10, batch_slots=16, max_iters=150
            ),
            lambda: requests(
                16,
                seed=1,
                rel_tols=[1e-2 if i % 2 == 0 else 1e-8 for i in range(16)],
            ),
        ),
    }

    out = {"n_devices": n_dev, "device_counts": counts, "cases": {}}
    for name, (cfg, make_reqs) in cases.items():
        log.info("case %s ...", name)
        per_count = {}
        migrations = {}
        for c in counts:
            sched = BatchScheduler(cfg, family, devices=jax.devices()[:c])
            results = list(sched.serve(make_reqs()))
            per_count[c] = _tuples(results)
            migrations[c] = sched.last_stats["migrations"]
            log.debug(
                "  devices=%d: %d results, %d migrations",
                c,
                len(results),
                migrations[c],
            )
        # rebalancing must be a pure placement change: identical results off
        off = BatchScheduler(
            QuadratureConfig(**{**cfg.__dict__, "rebalance": "off"}),
            family,
            devices=jax.devices()[: counts[-1]],
        )
        per_count["off"] = _tuples(list(off.serve(make_reqs())))
        # the windowed advance must be a pure cost change: identical results
        # with the full-capacity advance, on the biggest mesh
        adv_off = BatchScheduler(
            QuadratureConfig(**{**cfg.__dict__, "advance_window": False}),
            family,
            devices=jax.devices()[: counts[-1]],
        )
        per_count["adv_off"] = _tuples(list(adv_off.serve(make_reqs())))
        ref = per_count[1]
        for key, tuples in per_count.items():
            assert tuples == ref, (
                name,
                key,
                [a for a, b in zip(tuples, ref) if a != b][:2],
            )
        statuses = sorted({t[3] for t in ref})
        admitted = sorted({t[6] for t in ref})
        out["cases"][name] = {
            "statuses": statuses,
            "midflight_admissions": sum(1 for t in ref if t[6] > 0),
            "migrations": migrations,
            "parity": True,
            "n_results": len(ref),
            "admitted_at": admitted,
        }

        if name == "rebalanced":
            # recorder-attached replay of the migration-heavy case on the
            # biggest mesh: telemetry must not perturb a single bit, the
            # Chrome trace must be structurally valid with >=1 migration
            # flow pair, and the idle-fraction timeline must equal the
            # fig-4b formula recomputed by hand from the raw gauge events
            from repro.telemetry import MemorySink, Recorder, loadview
            from repro.telemetry.check import check_trace
            from repro.telemetry.trace import write_chrome_trace

            c = counts[-1]
            sink = MemorySink()
            rec = Recorder(sinks=(sink,))
            sched = BatchScheduler(
                cfg, family, devices=jax.devices()[:c], recorder=rec
            )
            tuples = _tuples(list(sched.serve(make_reqs())))
            rec.close()
            assert tuples == per_count[c], (
                "recorder-on run diverged from recorder-off run",
                [a for a, b in zip(tuples, per_count[c]) if a != b][:2],
            )
            flows = [
                e
                for e in sink.events
                if e["kind"] == "flow_begin" and e["name"] == "service.migrate"
            ]
            assert len(flows) == sched.last_stats["migrations"] > 0, (
                len(flows),
                sched.last_stats,
            )
            per_dev = cfg.batch_slots // c
            tl = loadview.occupancy_from_events(sink.events)
            assert tl.devices == list(range(c)), tl.devices
            idle = loadview.idle_fraction(tl, per_dev)
            # hand recompute straight from the gauge events (fig-4b: idle
            # fraction = 1 - occupied slot-iterations / total capacity)
            occ = {}
            its = set()
            for e in sink.events:
                if e["kind"] == "gauge" and e["name"] == "service.n_live":
                    occ.setdefault(e["lane"], 0.0)
                    occ[e["lane"]] += e["value"]
                    its.add(e["it"])
            for dev in range(c):
                hand = 1.0 - occ.get(dev, 0.0) / (len(its) * per_dev)
                assert abs(idle[dev] - hand) < 1e-12, (dev, idle[dev], hand)
            with tempfile.TemporaryDirectory() as tmp:
                path = os.path.join(tmp, "trace.json")
                write_chrome_trace(path, sink.events)
                problems = check_trace(path, n_devices=c, expect_flow=True)
                assert not problems, problems
            out["cases"][name]["telemetry"] = {
                "devices": c,
                "parity": True,
                "migration_flows": len(flows),
                "idle_fraction": [idle[d] for d in range(c)],
                "trace_check": "ok",
            }
            log.debug(
                "  telemetry replay: %d migration flows, idle=%s",
                len(flows),
                [round(idle[d], 3) for d in range(c)],
            )

    # the drain-heavy case must actually exercise migration on a real ring
    for c in counts[1:]:
        assert out["cases"]["rebalanced"]["migrations"][c] > 0, out
    assert "capacity" in out["cases"]["evicted"]["statuses"], out
    assert "max_iters" in out["cases"]["max_iters"]["statuses"], out
    assert out["cases"]["converged_midflight"]["midflight_admissions"] > 0, out

    print("RESULT_JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
