"""Pluggable quadrature-rule layer (paper: "accommodates multiple rules")."""

from __future__ import annotations

from typing import Callable, Protocol

import jax.numpy as jnp

from repro.core import gauss_kronrod, genz_malik
from repro.core.config import QuadratureConfig
from repro.core.error import two_level_error
from repro.core.integrands import get as get_integrand, parse_spec


class Rule(Protocol):
    n_evals_per_region: int

    def eval_batch(
        self, centers: jnp.ndarray, halfw: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(B, d) regions -> (est, err, split_axis) each of shape (B,)."""
        ...


def _select_axis(diffs: jnp.ndarray, halfw: jnp.ndarray) -> jnp.ndarray:
    """argmax fourth-difference; fall back to widest axis when flat."""
    eps = jnp.finfo(diffs.dtype).eps
    best = jnp.argmax(diffs, axis=-1).astype(jnp.int32)
    widest = jnp.argmax(halfw, axis=-1).astype(jnp.int32)
    flat = jnp.max(diffs, axis=-1) <= eps * 100.0
    return jnp.where(flat, widest, best)


class GenzMalikRule:
    """Degree-7 GM rule + two-level error + fourth-difference axis choice.

    ``theta`` switches the rule into ParamIntegrand-family mode: ``integrand``
    is then a family function ``f(x, theta)`` and theta may be a traced value
    (the batch service vmaps it over the problem axis).  On the kernel path
    theta enters ``pallas_call`` as a broadcast operand (see ``kernels.ops``)
    rather than a closure, which is what makes the fused kernel usable for
    families at all.
    """

    def __init__(
        self,
        d: int,
        integrand: Callable[..., jnp.ndarray],
        noise_mult: float = 50.0,
        use_kernel: bool = False,
        interpret: bool = True,
        block_regions: int = 0,  # 0 = kernels.ops.DEFAULT_BLOCK_REGIONS
        theta=None,
    ):
        self.d = d
        self.f = integrand
        self.theta = theta
        self.noise_mult = noise_mult
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.block_regions = block_regions
        self.n_evals_per_region = genz_malik.n_nodes(d)

    def eval_batch(self, centers, halfw):
        if self.use_kernel:
            from repro.kernels import ops as kernel_ops

            i7, i5, i3, diffs = kernel_ops.genz_malik_eval(
                self.f,
                centers,
                halfw,
                theta=self.theta,
                interpret=self.interpret,
                block_regions=self.block_regions,
            )
        else:
            f = (
                self.f
                if self.theta is None
                else lambda x: self.f(x, self.theta)
            )
            i7, i5, i3, diffs = genz_malik.gm_eval_reference(f, centers, halfw)
        vol = jnp.prod(2.0 * halfw, axis=-1)
        maxdiff = jnp.max(diffs, axis=-1)
        err = two_level_error(i7, i5, i3, vol, maxdiff, self.noise_mult)
        axis = _select_axis(diffs, halfw)
        return i7, err, axis


class GaussKronrodRule:
    """Tensor-product (G7, K15); cost 15^d — low/moderate d only (paper)."""

    def __init__(
        self,
        d: int,
        integrand: Callable[[jnp.ndarray], jnp.ndarray],
        chunk: int = 512,
        safety: float = 1.0,
    ):
        if d > 6:
            raise ValueError(
                f"tensor Gauss-Kronrod is prohibitive for d={d} (15^d nodes); "
                "the paper restricts it to low/moderate dimensions"
            )
        self.d = d
        self.f = integrand
        self.chunk = chunk
        self.safety = safety
        self.n_evals_per_region = gauss_kronrod.n_nodes(d)

    def eval_batch(self, centers, halfw):
        i_k, i_g, axis_disc = gauss_kronrod.gk_eval_batch(
            self.f, centers, halfw, chunk=self.chunk
        )
        err = self.safety * jnp.abs(i_k - i_g)
        # round-off floor
        eps = jnp.finfo(i_k.dtype).eps
        vol = jnp.prod(2.0 * halfw, axis=-1)
        err = jnp.maximum(err, 50.0 * eps * (jnp.abs(i_k) + vol))
        axis = _select_axis(axis_disc, halfw)
        return i_k, err, axis


def make_rule(cfg: QuadratureConfig, integrand=None, theta=None) -> Rule:
    """Build the configured rule.

    ``integrand`` overrides the config-named integrand with a plain callable
    ``f(x)``; passing ``theta`` as well marks it a ParamIntegrand family
    function ``f(x, theta)`` whose coefficients may be traced values (the
    batch service's per-slot theta).  A config-named family spec (e.g.
    ``"genz_gaussian:5,5:0.3,0.7"``) on the kernel path is parsed into the
    same (family fn, theta) pair so the fused kernel receives theta as an
    operand instead of a rejected captured constant.
    """
    if theta is not None and integrand is None:
        raise ValueError("theta requires an explicit family integrand")
    if integrand is not None:
        f = integrand
    elif cfg.use_kernel and ":" in cfg.integrand:
        # Family specs close over theta coefficient arrays when bound via
        # integrands.get(); the kernel path instead feeds theta through the
        # operand protocol of kernels.ops.genz_malik_eval.
        family, theta = parse_spec(cfg.integrand)
        f = family.fn
    else:
        f = get_integrand(cfg.integrand).fn
    if cfg.rule == "genz_malik":
        return GenzMalikRule(
            cfg.d,
            f,
            noise_mult=cfg.noise_mult,
            use_kernel=cfg.use_kernel,
            interpret=cfg.interpret,
            block_regions=cfg.block_regions,
            theta=theta,
        )
    if cfg.rule == "gauss_kronrod":
        if theta is not None:
            fam_f = f
            bound_theta = theta
            f = lambda x: fam_f(x, bound_theta)  # noqa: E731
        return GaussKronrodRule(cfg.d, f)
    raise ValueError(f"unknown rule {cfg.rule!r}")
