"""Heuristic region classifier: finalise regions with negligible error.

Two modes, mirroring the paper's single-GPU comparison:

- ``robust`` (our solver): a region is finalised when its error estimate fits
  inside its *volume-proportional share* of the global error budget.  This is
  conservative: peaked tails keep refining until the budget is genuinely met,
  which is what makes the solver robust on oscillatory/discontinuous
  integrands at tight tolerances (paper, Fig. 2).

- ``aggressive`` (PAGANI-like baseline): a region is finalised when its error
  is small *relative to its own integral estimate* (plus a small absolute
  floor).  This prunes hard in regions where the integrand is tiny (e.g.
  Gaussian tails) — fast on peaked integrands, but it can overshoot the
  target accuracy exactly as the paper observes for f4 and stall on f1.

Numerical guards (Gander-Gautschi [4]) are applied in both modes: a region
whose width has collapsed to the resolution floor, or whose error estimate
sits at the round-off noise floor, is finalised regardless, preventing
infinite refinement around singularities/discontinuities.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import QuadratureConfig


def error_budget(cfg: QuadratureConfig, global_estimate: jnp.ndarray) -> jnp.ndarray:
    """The paper's stopping threshold: max(abs_tol, |I| * rel_tol)."""
    return jnp.maximum(cfg.abs_tol, jnp.abs(global_estimate) * cfg.rel_tol)


def nonfinite_mask(
    est: jnp.ndarray, err: jnp.ndarray, active: jnp.ndarray
) -> jnp.ndarray:
    """Mask of active regions whose estimates went non-finite.

    A single NaN/Inf region estimate (an integrand pole, an overflowing
    parameterization, corrupted state) would otherwise poison every global
    reduction it enters — NaN propagates through the sum, the convergence
    check ``error <= budget`` is False forever, and the slot grinds to
    ``max_iters`` while polluting fleet-wide metrics.  Callers quarantine
    the flagged regions (zero their contributions, deactivate them) and
    report the slot with the terminal status ``nonfinite`` instead.
    """
    return active & ~(jnp.isfinite(est) & jnp.isfinite(err))


def classify(
    cfg: QuadratureConfig,
    est: jnp.ndarray,
    err: jnp.ndarray,
    halfw: jnp.ndarray,
    active: jnp.ndarray,
    global_estimate: jnp.ndarray,
    total_volume: float,
    domain_width: jnp.ndarray,
    n_active: jnp.ndarray | None = None,
    budget: jnp.ndarray | None = None,
    rel_tol: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Return the mask of active regions to finalise this iteration.

    ``n_active`` is the *global* active-region count in distributed runs
    (so every device applies the same equal-share threshold); defaults to
    the local count.  ``budget`` and ``rel_tol`` override the config-derived
    error budget and relative tolerance — the batch service passes
    per-request tolerances as traced values, so neither threshold can be
    baked in from ``cfg`` there (``rel_tol`` only affects the aggressive
    classifier's local-prune term).
    """
    if budget is None:
        budget = error_budget(cfg, global_estimate)
    if rel_tol is None:
        rel_tol = cfg.rel_tol
    vol = jnp.prod(2.0 * halfw, axis=-1)
    if n_active is None:
        n_active = jnp.sum(active)
    n_active = jnp.maximum(n_active, 1)

    if cfg.classifier == "robust":
        # Equal-share allocation: a region is negligible when its error fits
        # in a 1/4-safety equal share of the budget.  Scale-free: unlike a
        # volume-proportional share this does not starve peaked integrands
        # (whose mass sits in tiny-volume regions) nor explode the region
        # population on heavy tails.
        share = 0.25 * budget / n_active.astype(err.dtype)
        small = err <= share
    else:  # aggressive, PAGANI-like: prune relative to the LOCAL estimate.
        # Fast where the integrand is tiny (Gaussian tails) but can overshoot
        # the global target exactly as the paper reports for f4.
        small = err <= jnp.maximum(
            rel_tol * jnp.abs(est), 0.25 * budget / n_active.astype(err.dtype)
        )

    # minimum refinement depth before a region may be finalised (see
    # QuadratureConfig.min_depth_per_axis)
    deep = vol <= total_volume / 2.0 ** (cfg.min_depth_per_axis * cfg.d) * (
        1.0 + 1e-12
    )
    small = small & deep

    # --- numerical guards ----------------------------------------------------
    eps = jnp.finfo(est.dtype).eps
    width_floor = jnp.any(
        halfw <= cfg.min_width_frac * domain_width[None, :], axis=-1
    )
    noise = err <= cfg.noise_mult * eps * (jnp.abs(est) + vol)
    guard = width_floor | noise

    return active & (small | guard)
