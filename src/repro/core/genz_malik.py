"""Genz-Malik fully-symmetric embedded cubature rules.

Implements the degree-7 Genz-Malik rule [Genz & Malik 1983] on the reference
cube ``[-1, 1]^d`` together with its embedded degree-5 and degree-3 members,
which drive the Berntsen-Espelid-Genz style two-level error heuristic
(``repro.core.error``) and the fourth-divided-difference axis selection
heuristic used by Cuba/cubature and by the paper.

Node layout (counts for dimension ``d``):

    group 0: centre                                   1
    group 1: (+-lam2, 0, ..., 0) and perms            2d
    group 2: (+-lam3, 0, ..., 0) and perms            2d
    group 3: (+-lam4, +-lam4, 0, ..., 0) and perms    2d(d-1)
    group 4: (+-lam5, ..., +-lam5)                    2^d

    total n(d) = 1 + 4d + 2d(d-1) + 2^d

All weights are exact rationals evaluated in float64; exactness on
polynomials of total degree <= 7 (resp. 5, 3) is asserted by the tests.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# Reference-cube generator radii (squared values are exact rationals).
LAMBDA2 = float(np.sqrt(9.0 / 70.0))
LAMBDA3 = float(np.sqrt(9.0 / 10.0))
LAMBDA4 = float(np.sqrt(9.0 / 10.0))
LAMBDA5 = float(np.sqrt(9.0 / 19.0))

# Ratio used by the fourth-divided-difference axis heuristic.
FOURTH_DIFF_RATIO = (9.0 / 70.0) / (9.0 / 10.0)  # lam2^2 / lam3^2 == 1/7


def n_nodes(d: int) -> int:
    """Total number of integrand evaluations of the GM rule in dimension d."""
    return 1 + 4 * d + 2 * d * (d - 1) + 2**d


@dataclasses.dataclass(frozen=True)
class GMWeights:
    """Weights of the embedded degree-7/5/3 GM family (volume included).

    ``w*`` are per-node weights on [-1,1]^d; multiplying the weighted node
    sum by ``prod(halfwidths)`` yields the integral over the actual box
    (the 2^d reference volume is folded into the weights).
    """

    d: int
    # degree-7 rule
    w1: float
    w2: float
    w3: float
    w4: float
    w5: float
    # embedded degree-5 rule (groups 0..3 only)
    e1: float
    e2: float
    e3: float
    e4: float
    # embedded degree-3 rule (centre + lam3 group only)
    t1: float
    t3: float


@functools.lru_cache(maxsize=None)
def gm_weights(d: int) -> GMWeights:
    if d < 1:
        raise ValueError(f"Genz-Malik rule needs d >= 1, got {d}")
    if d == 1:
        # Degree-7 weights w4 multiply an empty group in d=1; keep zero.
        pass
    vol = float(2**d)
    w1 = vol * (12824.0 - 9120.0 * d + 400.0 * d * d) / 19683.0
    w2 = vol * 980.0 / 6561.0
    w3 = vol * (1820.0 - 400.0 * d) / 19683.0
    w4 = vol * 200.0 / 19683.0
    w5 = vol * 6859.0 / 19683.0 / (2**d)

    e1 = vol * (729.0 - 950.0 * d + 50.0 * d * d) / 729.0
    e2 = vol * 245.0 / 486.0
    e3 = vol * (265.0 - 100.0 * d) / 1458.0
    e4 = vol * 25.0 / 729.0

    # Degree-3 rule using the centre and the lam3 single-coordinate group:
    #   2 * t3 * lam3^2 = vol / 3  (per-axis second moment)
    t3 = vol / (6.0 * (9.0 / 10.0))
    t1 = vol - 2.0 * d * t3
    return GMWeights(d, w1, w2, w3, w4, w5, e1, e2, e3, e4, t1, t3)


def pair_generators(d: int) -> np.ndarray:
    """Static (n_pairs*4, 2, 2) array of ((i, si), (j, sj)) for group 3."""
    out = []
    for i in range(d):
        for j in range(i + 1, d):
            for si in (1.0, -1.0):
                for sj in (1.0, -1.0):
                    out.append(((i, si), (j, sj)))
    return np.array(out, dtype=object)


def _eval_axis_groups(f, centers, halfw, dtype):
    """Single-coordinate displacement sums + per-axis fourth differences.

    centers/halfw: (d, B).  Returns (sum2, sum3, f0, fourth_diff) with
    sum2/sum3/f0 of shape (B,) and fourth_diff (d, B).
    """
    d = centers.shape[0]
    f0 = f(centers)
    sum2 = jnp.zeros_like(f0)
    sum3 = jnp.zeros_like(f0)
    diffs = []
    rows = jnp.arange(d)[:, None]
    for i in range(d):
        onehot = (rows == i).astype(dtype)
        d2 = LAMBDA2 * halfw[i] * onehot
        d3 = LAMBDA3 * halfw[i] * onehot
        f2p = f(centers + d2)
        f2m = f(centers - d2)
        f3p = f(centers + d3)
        f3m = f(centers - d3)
        sum2 = sum2 + f2p + f2m
        sum3 = sum3 + f3p + f3m
        diffs.append(
            jnp.abs(f2p + f2m - 2.0 * f0 - FOURTH_DIFF_RATIO * (f3p + f3m - 2.0 * f0))
        )
    return sum2, sum3, f0, jnp.stack(diffs, axis=0)


def _eval_pair_group(f, centers, halfw, dtype):
    """Group 3 sum: (+-lam4, +-lam4) over all axis pairs.  (B,)."""
    d = centers.shape[0]
    total = jnp.zeros(centers.shape[1], dtype=dtype)
    rows = jnp.arange(d)[:, None]
    for i in range(d):
        for j in range(i + 1, d):
            ei = (rows == i).astype(dtype)
            ej = (rows == j).astype(dtype)
            di = LAMBDA4 * halfw[i] * ei
            dj = LAMBDA4 * halfw[j] * ej
            total = (
                total
                + f(centers + di + dj)
                + f(centers + di - dj)
                + f(centers - di + dj)
                + f(centers - di - dj)
            )
    return total


def _eval_corner_group(f, centers, halfw, dtype):
    """Group 4 sum: full-sign (+-lam5, ..., +-lam5) points via fori_loop."""
    d, b = centers.shape

    def body(k, acc):
        # signs from the bits of k: axis i sign = +1 if bit clear else -1
        bits = jnp.stack([(k >> i) & 1 for i in range(d)]).astype(dtype)
        signs = 1.0 - 2.0 * bits  # (d,)
        x = centers + LAMBDA5 * halfw * signs[:, None]
        return acc + f(x)

    return jax.lax.fori_loop(0, 2**d, body, jnp.zeros(b, dtype=dtype))


def gm_eval_reference(
    f: Callable[[jnp.ndarray], jnp.ndarray],
    centers: jnp.ndarray,
    halfw: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pure-jnp oracle for the batched GM evaluation.

    Args:
      f: integrand mapping (d, N) coordinates -> (N,) values.
      centers, halfw: (B, d) region centres / halfwidths.

    Returns:
      (i7, i5, i3, fourth_diff): degree-7/5/3 estimates (B,) each, already
      scaled by the region volume factor prod(halfw), and the per-axis
      fourth differences (B, d) for axis selection.
    """
    dtype = centers.dtype
    b, d = centers.shape
    w = gm_weights(d)
    ct = centers.T  # (d, B) SoA layout
    ht = halfw.T

    sum2, sum3, f0, diffs = _eval_axis_groups(f, ct, ht, dtype)
    sum4 = _eval_pair_group(f, ct, ht, dtype)
    sum5 = _eval_corner_group(f, ct, ht, dtype)

    scale = jnp.prod(ht, axis=0)  # (B,)
    i7 = scale * (w.w1 * f0 + w.w2 * sum2 + w.w3 * sum3 + w.w4 * sum4 + w.w5 * sum5)
    i5 = scale * (w.e1 * f0 + w.e2 * sum2 + w.e3 * sum3 + w.e4 * sum4)
    i3 = scale * (w.t1 * f0 + w.t3 * sum3)
    return i7, i5, i3, diffs.T  # (B,), (B,), (B,), (B, d)
