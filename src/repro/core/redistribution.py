"""Decentralised cyclic load redistribution (the paper's §3 contribution).

Pairing: at iteration ``t`` every device pairs with the rank at ring
distance ``s = schedule[t mod len(schedule)]`` — the paper's "cyclic
round-robin policy".  Because XLA SPMD collectives require *static*
communication patterns, the shift sequence is a compile-time schedule and
each round is dispatched through ``lax.switch`` over per-shift branches
(DESIGN.md §2).  The default schedule front-loads power-of-two strides,
which map onto ICI torus dimensions — addressing the topology-blindness the
paper lists as a limitation of its own round-robin.

Transfer protocol per round (all inside one shard_map body):

  phase 1 (stats):   ppermute of a 4-int vector [n_rows, free, surplus,
                     deficit] from each rank to its upstream neighbour, so
                     donors see their receiver's capacity, and the mirror
                     direction so receivers know what is coming.
  phase 2 (payload): ppermute of a fixed ``(cap, 2d)`` buffer carrying ONLY
                     subregion coordinates (centres ++ halfwidths) — the
                     paper transfers "subregion coordinates rather than full
                     data structures"; receivers mark them fresh and
                     re-evaluate.

A transfer happens only donor->receiver (a rank with surplus never has a
deficit, so at most one direction of each pair is live — donor/donor pairs
idle, the same limitation the paper documents).  The transferred regions are
the *largest-error* ones: `split.classify_split_compact` stores the B-child
of the highest-error parents at the tail of the occupied block, so the tail
window [n_rows - n_send, n_rows) is exactly "the top of the sorted error
list", and removing it keeps the occupied block contiguous with no extra
compaction pass.

Conservative in-flight accounting: convergence metadata is psum'd *before*
redistribution from fully-evaluated regions, and a transferred region is
re-evaluated by its receiver before the next metadata exchange — every
region is therefore counted in every global error estimate exactly once
(DESIGN.md §4), which is the structural version of the paper's "in-flight
estimates that conservatively bound the contribution of subregions
currently in transit".
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.region_store import RegionState


def make_schedule(n_devices: int, max_len: int = 8) -> tuple[int, ...]:
    """Ring-shift schedule: powers of two first (ICI-torus friendly), then
    the remaining strides in ascending order up to ``max_len`` entries."""
    if n_devices <= 1:
        return ()
    shifts: list[int] = []
    s = 1
    while s < n_devices and len(shifts) < max_len:
        shifts.append(s)
        s <<= 1
    s = 3
    while len(shifts) < min(n_devices - 1, max_len):
        if s < n_devices and s not in shifts:
            shifts.append(s)
        s += 1
    return tuple(shifts)


def ring_perms(n: int, shift: int) -> tuple[list, list]:
    """The two ppermute index lists of one cyclic round at ring distance
    ``shift``: ``down`` routes rank ``i``'s data to ``i - shift`` (so every
    rank sees its downstream partner ``i + shift``), ``up`` routes to
    ``i + shift`` (payload direction: donor ``i`` feeds ``i + shift``).

    Shared by region-level :func:`redistribute` and the batch service's
    problem-level rebalancer — both implement the paper's cyclic round-robin
    pairing, at different granularities.
    """
    down = [(i, (i - shift) % n) for i in range(n)]  # i's stats -> upstream
    up = [(i, (i + shift) % n) for i in range(n)]  # payload / stats downstream
    return down, up


_ring_perms = ring_perms  # backward-compatible private alias


def check_ring_invariants(n_devices: int) -> None:
    """Assert the schedule/permutation invariants for an ``n_devices`` ring.

    Every shift in :func:`make_schedule` must be a nonzero ring distance
    strictly below ``n_devices``, with no duplicates, and each of its
    :func:`ring_perms` directions must be a bijection on ranks with the two
    directions mutually inverse.  The trivial ring (``n_devices <= 1``) has
    an empty schedule.  Used by the chaos selftest to certify that an
    elastically shrunken mesh still presents a valid cyclic-pairing topology
    to the compiled collectives.
    """
    schedule = make_schedule(n_devices)
    if n_devices <= 1:
        assert schedule == (), schedule
        return
    assert len(set(schedule)) == len(schedule), schedule
    ranks = list(range(n_devices))
    for shift in schedule:
        assert 0 < shift < n_devices, (shift, n_devices)
        down, up = ring_perms(n_devices, shift)
        for perm in (down, up):
            assert sorted(s for s, _ in perm) == ranks, perm
            assert sorted(d for _, d in perm) == ranks, perm
        assert {(d, s) for s, d in down} == set(up), (down, up)


def exchange_pair_stats(
    stats: jnp.ndarray, axis_name: str, n_devices: int, shift: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Phase-1 stats swap of a cyclic round (see module docstring).

    Returns ``(down_stats, up_stats)``: on rank ``i``, ``down_stats`` is the
    stats vector of its receiver ``i + shift`` and ``up_stats`` that of its
    donor ``i - shift`` — both sides of a pair can therefore agree on the
    transfer size from the same four numbers without a second round trip.
    """
    down, up = ring_perms(n_devices, shift)
    return (
        jax.lax.ppermute(stats, axis_name, down),
        jax.lax.ppermute(stats, axis_name, up),
    )


def dispatch_cyclic(schedule: Sequence[int], t, make_round, *operands):
    """Run round ``t`` of a static cyclic schedule via ``lax.switch``.

    XLA SPMD collectives need compile-time communication patterns, so every
    shift in ``schedule`` is traced into its own branch (``make_round(shift)``
    returns the round body) and the iteration counter picks the branch at run
    time.  This is the pairing discipline shared by region redistribution and
    the batch service's problem migration.
    """
    branches = [make_round(s) for s in schedule]
    return jax.lax.switch(jnp.mod(t, len(schedule)), branches, *operands)


def redistribute(
    state: RegionState,
    *,
    axis_name: str,
    n_devices: int,
    schedule: Sequence[int],
    cap: int,
    limit: int,
) -> RegionState:
    """One redistribution round (inside shard_map). See module docstring."""
    if n_devices <= 1 or not schedule:
        return state

    C = state.capacity
    d = state.d
    idx = jnp.arange(C)
    j = jnp.arange(cap)

    n_rows = jnp.sum(state.active).astype(jnp.int32)
    total = jax.lax.psum(n_rows, axis_name)
    fair_lo = total // n_devices
    fair_hi = -(-total // n_devices)  # ceil
    surplus = jnp.maximum(n_rows - fair_hi, 0)
    deficit = jnp.maximum(fair_lo - n_rows, 0)
    free = jnp.maximum(jnp.int32(limit) - n_rows, 0)
    stats = jnp.stack([n_rows, free, surplus, deficit])

    def round_fn(shift: int):
        _, perm_up = ring_perms(n_devices, shift)

        def fn(state: RegionState) -> RegionState:
            # --- phase 1: stats both ways ---------------------------------
            down_stats, up_stats = exchange_pair_stats(
                stats, axis_name, n_devices, shift
            )
            _, down_free, _, down_deficit = down_stats
            _, _, up_surplus, _ = up_stats

            n_send = jnp.minimum(
                jnp.minimum(jnp.int32(cap), surplus),
                jnp.minimum(down_deficit, down_free),
            )
            n_recv = jnp.minimum(
                jnp.minimum(jnp.int32(cap), up_surplus),
                jnp.minimum(deficit, free),
            )

            # --- phase 2: payload (coordinates only) ----------------------
            src = jnp.clip(n_rows - n_send + j, 0, C - 1)
            valid_send = j < n_send
            payload = jnp.concatenate(
                [state.centers[src], state.halfw[src]], axis=1
            )  # (cap, 2d)
            payload = jnp.where(valid_send[:, None], payload, 0.0)
            incoming = jax.lax.ppermute(payload, axis_name, perm_up)

            # --- donor side: retire the sent tail window -------------------
            sent = (idx >= n_rows - n_send) & (idx < n_rows)
            active = state.active & ~sent
            fresh = state.fresh & ~sent

            # --- receiver side: splice into the contiguous tail ------------
            base = n_rows - n_send
            dest = jnp.where(j < n_recv, base + j, C)  # C = dropped
            centers = state.centers.at[dest].set(incoming[:, :d], mode="drop")
            halfw = state.halfw.at[dest].set(incoming[:, d:], mode="drop")
            active = active.at[dest].set(True, mode="drop")
            fresh = fresh.at[dest].set(True, mode="drop")
            est = state.est.at[dest].set(0.0, mode="drop")
            err = state.err.at[dest].set(0.0, mode="drop")
            axv = state.axis.at[dest].set(0, mode="drop")
            return dataclasses.replace(
                state,
                centers=centers,
                halfw=halfw,
                est=est,
                err=err,
                axis=axv,
                active=active,
                fresh=fresh,
            )

        return fn

    return dispatch_cyclic(schedule, state.it, round_fn, state)


def balance_stats(n_rows: jnp.ndarray, axis_name: str, n_devices: int):
    """(max, mean, imbalance) of per-device active counts — the idle-time
    proxy reported in the Fig. 4b benchmark (idle ~ 1 - mean/max)."""
    total = jax.lax.psum(n_rows, axis_name)
    biggest = jax.lax.pmax(n_rows, axis_name)
    mean = total / n_devices
    imb = jnp.where(biggest > 0, 1.0 - mean / jnp.maximum(biggest, 1), 0.0)
    return biggest, mean, imb
