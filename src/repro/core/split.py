"""Fused filter + split + compaction (the paper's fused GPU stage).

One sort-based pass that (i) folds finalised regions into the scalar
accumulators, (ii) compacts survivors to the front ordered by descending
error, and (iii) splits as many survivors as capacity allows along their
assigned axes (children replace the parent slot and append after the
survivor block, so all fresh children occupy a predictable range).

On GPU the paper fuses filtering and splitting into a single kernel to cut
data movement; under XLA the whole step is one compiled module, so the fusion
here is algorithmic (single argsort, single gather) rather than a hand-written
kernel — see DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.region_store import RegionState


def classify_split_compact(
    state: RegionState, finalize_mask: jnp.ndarray
) -> RegionState:
    """Apply the classifier verdict, then split every surviving region.

    Under capacity pressure only the top-(free slots) regions by error are
    split; the rest stay active-but-unsplit (their estimates remain valid,
    they are split on a later iteration).  ``overflowed`` records that
    pressure was ever hit — this is the feasibility limit of Fig. 3a.
    """
    C = state.capacity
    fin = finalize_mask & state.active
    fin_integral = state.fin_integral + jnp.sum(jnp.where(fin, state.est, 0.0))
    fin_error = state.fin_error + jnp.sum(jnp.where(fin, state.err, 0.0))
    active = state.active & ~fin

    # Sort key: survivors by descending error first, then freed/inactive slots.
    big = jnp.asarray(jnp.finfo(state.err.dtype).max, state.err.dtype)
    key = jnp.where(active, -state.err, big)
    perm = jnp.argsort(key)

    centers = state.centers[perm]
    halfw = state.halfw[perm]
    est = state.est[perm]
    err = state.err[perm]
    axis = state.axis[perm]
    active = active[perm]

    n_act = jnp.sum(active)
    idx = jnp.arange(C)

    # Graceful degradation under memory pressure (the paper's Fig. 3a
    # feasibility limit): if the store is nearly full, force-finalise the
    # *lowest-error* tail so splitting can always make progress.  Their
    # (conservative) error estimates are folded into the accumulators, so the
    # global bound remains honest; without this, a full store deadlocks
    # (n_act == C allows zero splits and the classifier threshold, which
    # scales as budget/n_act, can no longer finalise anything).
    limit = 3 * C // 4
    forced = active & (idx >= limit)
    fin_integral = fin_integral + jnp.sum(jnp.where(forced, est, 0.0))
    fin_error = fin_error + jnp.sum(jnp.where(forced, err, 0.0))
    active = active & ~forced
    n_act = jnp.minimum(n_act, limit)

    k = jnp.minimum(n_act, C - n_act)  # number of regions we can split (+1 slot each)
    overflowed = state.overflowed | (k < n_act) | jnp.any(forced)

    split_row = idx < k  # rows being split (highest error first)

    onehot = jnp.arange(state.d)[None, :] == axis[:, None]  # (C, d)
    h_half = jnp.where(onehot, 0.5 * halfw, halfw)
    # children tile the parent exactly: centres at c -+ h/2 along the axis
    shift = jnp.where(onehot, h_half, 0.0)

    child_a_centers = centers - shift
    child_b_centers = centers + shift

    # Child A overwrites the parent row.
    centers = jnp.where(split_row[:, None], child_a_centers, centers)
    halfw = jnp.where(split_row[:, None], h_half, halfw)

    # Child B appended after the survivor block in REVERSED error order
    # (row i -> n_act + k - 1 - i), so the occupied block's tail holds the
    # children of the highest-error parents — the redistribution layer sends
    # the tail window, which is then exactly "the largest-error subregions,
    # chosen after sorting" (paper §3) while keeping the block contiguous.
    dest = jnp.where(split_row, n_act + k - 1 - idx, C)  # C == OOB, dropped
    centers = centers.at[dest].set(child_b_centers, mode="drop")
    halfw = halfw.at[dest].set(h_half, mode="drop")

    active = active | (idx < n_act + k)
    fresh = split_row | ((idx >= n_act) & (idx < n_act + k))
    # Invalidate stale values on fresh rows so masked reductions stay exact.
    est = jnp.where(fresh, 0.0, est)
    err = jnp.where(fresh, 0.0, err)
    axis = jnp.where(fresh, 0, axis)

    return dataclasses.replace(
        state,
        centers=centers,
        halfw=halfw,
        est=est,
        err=err,
        axis=axis,
        active=active,
        fresh=fresh & active,
        fin_integral=fin_integral,
        fin_error=fin_error,
        overflowed=overflowed,
    )


def compact(state: RegionState) -> RegionState:
    """Compact actives to the front by descending error (no split)."""
    big = jnp.asarray(jnp.finfo(state.err.dtype).max, state.err.dtype)
    key = jnp.where(state.active, -state.err, big)
    perm = jnp.argsort(key)
    return dataclasses.replace(
        state,
        centers=state.centers[perm],
        halfw=state.halfw[perm],
        est=state.est[perm],
        err=state.err[perm],
        axis=state.axis[perm],
        active=state.active[perm],
        fresh=state.fresh[perm],
    )
