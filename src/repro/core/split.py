"""Fused filter + split + compaction (the paper's fused GPU stage).

One sort-based pass that (i) folds finalised regions into the scalar
accumulators, (ii) compacts survivors to the front ordered by descending
error, and (iii) splits as many survivors as capacity allows along their
assigned axes (children replace the parent slot and append after the
survivor block, so all fresh children occupy a predictable range).

On GPU the paper fuses filtering and splitting into a single kernel to cut
data movement; under XLA the whole step is one compiled module, so the fusion
here is algorithmic (single argsort, single gather) rather than a hand-written
kernel — see DESIGN.md §2.

**Windowed advance** (DESIGN.md §3).  Both entry points take an optional
``window`` so the sort, the gathers and the child writes run on the leading
``window`` rows only, leaving the tail ``[window, capacity)`` out of the
compiled computation entirely.  The caller owes two guarantees, both free
under the active-window invariant (every active slot lives in
``[0, n_active)``):

- every active slot is inside the window (so the sort sees the whole live
  population and the tail is all-inactive);
- ``window >= min(2 * n_active, capacity)`` (post-split the population can
  double, and under capacity pressure the child block extends to exactly
  ``capacity``).

The capacity-semantics scalars — the ``3C//4`` forced-finalise limit and the
split budget ``k = min(n_act, C - n_act)`` — stay defined against the FULL
capacity ``C``, never the window: whenever they could bite (``n_act > C/2``),
the second guarantee already forces the full-capacity window, so a windowed
advance is bit-identical to the legacy full one in every regime (argsort is
stable, so survivors order identically; freed-slot *garbage* may land in
different slots, but garbage is never re-exposed — every slot that becomes
active is overwritten with child data first).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.region_store import RegionState


def survivor_sort_perm(err: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Permutation compacting active slots to the front by descending error.

    The single source of truth for the compaction order, shared by
    :func:`classify_split_compact` and :func:`compact`: survivors sort by
    descending error estimate, freed/inactive slots sink to the back (stable,
    so equal keys — and the inactive block — keep their relative order; the
    windowed and full-capacity sorts therefore agree on every live slot).
    """
    big = jnp.asarray(jnp.finfo(err.dtype).max, err.dtype)
    return jnp.argsort(jnp.where(active, -err, big))


def _window(state: RegionState, window: Optional[int]) -> int:
    w = state.capacity if window is None else int(window)
    if not 0 < w <= state.capacity:
        raise ValueError(f"window {w} outside (0, {state.capacity}]")
    return w


def classify_split_compact(
    state: RegionState,
    finalize_mask: jnp.ndarray,
    window: Optional[int] = None,
) -> RegionState:
    """Apply the classifier verdict, then split every surviving region.

    Under capacity pressure only the top-(free slots) regions by error are
    split; the rest stay active-but-unsplit (their estimates remain valid,
    they are split on a later iteration).  ``overflowed`` records that
    pressure was ever hit — this is the feasibility limit of Fig. 3a.

    ``finalize_mask`` must have shape ``(window,)`` (``(capacity,)`` when
    ``window`` is ``None``); ``window`` obligations are in the module
    docstring.
    """
    C = state.capacity
    w = _window(state, window)
    act_w = state.active[:w]
    fin = finalize_mask & act_w
    fin_integral = state.fin_integral + jnp.sum(jnp.where(fin, state.est[:w], 0.0))
    fin_error = state.fin_error + jnp.sum(jnp.where(fin, state.err[:w], 0.0))
    active = act_w & ~fin

    perm = survivor_sort_perm(state.err[:w], active)

    centers = state.centers[:w][perm]
    halfw = state.halfw[:w][perm]
    est = state.est[:w][perm]
    err = state.err[:w][perm]
    axis = state.axis[:w][perm]
    active = active[perm]

    n_act = jnp.sum(active)
    idx = jnp.arange(w)

    # Graceful degradation under memory pressure (the paper's Fig. 3a
    # feasibility limit): if the store is nearly full, force-finalise the
    # *lowest-error* tail so splitting can always make progress.  Their
    # (conservative) error estimates are folded into the accumulators, so the
    # global bound remains honest; without this, a full store deadlocks
    # (n_act == C allows zero splits and the classifier threshold, which
    # scales as budget/n_act, can no longer finalise anything).  The limit is
    # a property of the store, not of the window: it can only bite when
    # n_act > 3C/4, which the window contract escalates to the full rung.
    limit = 3 * C // 4
    forced = active & (idx >= limit)
    fin_integral = fin_integral + jnp.sum(jnp.where(forced, est, 0.0))
    fin_error = fin_error + jnp.sum(jnp.where(forced, err, 0.0))
    active = active & ~forced
    n_act = jnp.minimum(n_act, limit)

    k = jnp.minimum(n_act, C - n_act)  # number of regions we can split (+1 slot each)
    overflowed = state.overflowed | (k < n_act) | jnp.any(forced)

    split_row = idx < k  # rows being split (highest error first)

    onehot = jnp.arange(state.d)[None, :] == axis[:, None]  # (w, d)
    h_half = jnp.where(onehot, 0.5 * halfw, halfw)
    # children tile the parent exactly: centres at c -+ h/2 along the axis
    shift = jnp.where(onehot, h_half, 0.0)

    child_a_centers = centers - shift
    child_b_centers = centers + shift

    # Child A overwrites the parent row.
    centers = jnp.where(split_row[:, None], child_a_centers, centers)
    halfw = jnp.where(split_row[:, None], h_half, halfw)

    # Child B appended after the survivor block in REVERSED error order
    # (row i -> n_act + k - 1 - i), so the occupied block's tail holds the
    # children of the highest-error parents — the redistribution layer sends
    # the tail window, which is then exactly "the largest-error subregions,
    # chosen after sorting" (paper §3) while keeping the block contiguous.
    # The window contract (w >= n_act + k) keeps every destination in-window.
    dest = jnp.where(split_row, n_act + k - 1 - idx, w)  # w == OOB, dropped
    centers = centers.at[dest].set(child_b_centers, mode="drop")
    halfw = halfw.at[dest].set(h_half, mode="drop")

    active = active | (idx < n_act + k)
    fresh = split_row | ((idx >= n_act) & (idx < n_act + k))
    # Invalidate stale values on fresh rows so masked reductions stay exact.
    est = jnp.where(fresh, 0.0, est)
    err = jnp.where(fresh, 0.0, err)
    axis = jnp.where(fresh, 0, axis)
    fresh = fresh & active

    if w == C:
        return dataclasses.replace(
            state,
            centers=centers,
            halfw=halfw,
            est=est,
            err=err,
            axis=axis,
            active=active,
            fresh=fresh,
            fin_integral=fin_integral,
            fin_error=fin_error,
            overflowed=overflowed,
        )
    # Write the window back; the untouched tail is all-inactive (and
    # fresh-free) by the window contract, so the full-state invariants hold.
    return dataclasses.replace(
        state,
        centers=state.centers.at[:w].set(centers),
        halfw=state.halfw.at[:w].set(halfw),
        est=state.est.at[:w].set(est),
        err=state.err.at[:w].set(err),
        axis=state.axis.at[:w].set(axis),
        active=state.active.at[:w].set(active),
        fresh=state.fresh.at[:w].set(fresh),
        fin_integral=fin_integral,
        fin_error=fin_error,
        overflowed=overflowed,
    )


def compact(state: RegionState, window: Optional[int] = None) -> RegionState:
    """Compact actives to the front by descending error (no split).

    ``window`` restricts the sort/gather to the leading rows; every active
    slot must already sit inside the window (post-compaction the population
    cannot grow, so ``window >= n_active`` suffices here).
    """
    w = _window(state, window)
    perm = survivor_sort_perm(state.err[:w], state.active[:w])
    leaves = dict(
        centers=state.centers[:w][perm],
        halfw=state.halfw[:w][perm],
        est=state.est[:w][perm],
        err=state.err[:w][perm],
        axis=state.axis[:w][perm],
        active=state.active[:w][perm],
        fresh=state.fresh[:w][perm],
    )
    if w == state.capacity:
        return dataclasses.replace(state, **leaves)
    return dataclasses.replace(
        state,
        **{k: getattr(state, k).at[:w].set(v) for k, v in leaves.items()},
    )
