"""Core library: the paper's adaptive multidimensional quadrature."""

from repro.core.adaptive import (
    AdaptiveResult,
    integrate,
    integrate_device,
    integrate_exact_check,
)
from repro.core.config import QuadratureConfig
from repro.core.integrands import REGISTRY as INTEGRANDS
from repro.core.rules import GaussKronrodRule, GenzMalikRule, make_rule

__all__ = [
    "AdaptiveResult",
    "GaussKronrodRule",
    "GenzMalikRule",
    "INTEGRANDS",
    "QuadratureConfig",
    "integrate",
    "integrate_device",
    "integrate_exact_check",
    "make_rule",
]
