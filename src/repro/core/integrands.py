"""Benchmark integrands from the paper (Section 4) + exact reference values.

All integrands use the SoA convention of the framework: ``f(x)`` receives
coordinates of shape ``(d, N)`` and returns values of shape ``(N,)``.  This
matches the paper's Structure-of-Arrays layout and the TPU lane layout used
by the Pallas kernel (regions on the 128-wide lane axis).

Exact values are analytic (separable products, the Genz corner-peak
inclusion-exclusion formula, and a multinomial DP for f7) over [0, 1]^d.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Integrand:
    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]  # (d, N) -> (N,)
    exact: Callable[[int], float]  # exact integral over [0,1]^d
    description: str = ""
    smooth: bool = True


def _axis_coeff(x: jnp.ndarray, start: int = 1) -> jnp.ndarray:
    """Per-axis coefficient ``start + axis`` broadcast over ``x``'s shape.

    Generated with a 2-D iota rather than a closed-over ``jnp.arange`` so
    that Pallas kernels which inline the integrand capture no constant
    arrays (pallas_call rejects captured consts).
    """
    return jax.lax.broadcasted_iota(x.dtype, x.shape, 0) + float(start)


# --- f1: oscillatory ---------------------------------------------------------


def f1(x: jnp.ndarray) -> jnp.ndarray:
    i = _axis_coeff(x)
    return jnp.cos(jnp.sum(i * x, axis=0))


def f1_exact(d: int) -> float:
    # cos(sum i x_i) = Re prod_k exp(i k x_k); each 1-D factor integrates to
    # (exp(i k) - 1) / (i k).
    p = complex(1.0, 0.0)
    for k in range(1, d + 1):
        p *= (np.exp(1j * k) - 1.0) / (1j * k)
    return float(p.real)


# --- f2: product peak --------------------------------------------------------

_F2_B2 = 50.0**-2


def f2(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.prod(1.0 / (_F2_B2 + (x - 0.5) ** 2), axis=0)


def f2_exact(d: int) -> float:
    b = 0.02
    one_dim = (2.0 / b) * math.atan(0.5 / b)
    return float(one_dim**d)


# --- f3: corner peak ---------------------------------------------------------


def f3(x: jnp.ndarray) -> jnp.ndarray:
    d = x.shape[0]
    i = _axis_coeff(x)
    return (1.0 + jnp.sum(i * x, axis=0)) ** (-(d + 1.0))


def f3_exact(d: int) -> float:
    # Inclusion-exclusion (Genz): 1/(d! prod c_i) sum_{v in {0,1}^d}
    #   (-1)^|v| / (1 + c . v),   c_i = i.
    c = list(range(1, d + 1))
    total = 0.0
    for mask in range(2**d):
        s = 1.0
        bits = 0
        for i in range(d):
            if (mask >> i) & 1:
                s += c[i]
                bits += 1
        total += (-1.0) ** bits / s
    return float(total / (math.factorial(d) * math.prod(c)))


# --- f4: Gaussian ------------------------------------------------------------


def f4(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp(-(25.0**2) * jnp.sum((x - 0.5) ** 2, axis=0))


def f4_exact(d: int) -> float:
    one_dim = math.sqrt(math.pi) / 25.0 * math.erf(12.5)
    return float(one_dim**d)


# --- f5: C0 (kink) -----------------------------------------------------------


def f5(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp(-10.0 * jnp.sum(jnp.abs(x - 0.5), axis=0))


def f5_exact(d: int) -> float:
    one_dim = 0.2 * (1.0 - math.exp(-5.0))
    return float(one_dim**d)


# --- f6: discontinuous -------------------------------------------------------


def f6(x: jnp.ndarray) -> jnp.ndarray:
    i = _axis_coeff(x)  # 1-based axis index
    cut = (3.0 + i) / 10.0
    inside = jnp.all(x <= cut, axis=0)
    val = jnp.exp(jnp.sum((i + 4.0) * x, axis=0))
    return jnp.where(inside, val, 0.0)


def f6_exact(d: int) -> float:
    p = 1.0
    for i in range(1, d + 1):
        c = i + 4.0
        u = min(1.0, (3.0 + i) / 10.0)
        p *= (math.exp(c * u) - 1.0) / c
    return float(p)


# --- f7: polynomial ridge ----------------------------------------------------

_F7_POW = 11


def f7(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x * x, axis=0) ** _F7_POW


@lru_cache(maxsize=None)
def _f7_dp(j: int, p: int) -> float:
    # F(j, p) = sum_{|k| = p over j dims} p!/prod(k!) prod E[x^{2 k_i}],
    # with E[x^{2k}] = 1/(2k+1) on [0,1].
    if j == 0:
        return 1.0 if p == 0 else 0.0
    total = 0.0
    for k in range(p + 1):
        total += math.comb(p, k) * (1.0 / (2 * k + 1)) * _f7_dp(j - 1, p - k)
    return total


def f7_exact(d: int) -> float:
    return float(_f7_dp(d, _F7_POW))


# --- auxiliary integrands for property tests & demos ------------------------


def make_monomial(powers: tuple[int, ...]) -> Integrand:
    """prod x_i^{p_i} with exact integral prod 1/(p_i + 1) over [0,1]^d."""
    p = np.asarray(powers, dtype=np.float64)

    def fn(x):
        return jnp.prod(x ** jnp.asarray(p, dtype=x.dtype)[:, None], axis=0)

    exact = float(np.prod(1.0 / (p + 1.0)))
    return Integrand(
        name=f"monomial{powers}", fn=fn, exact=lambda d: exact, smooth=True
    )


def make_genz_gaussian(a: np.ndarray, u: np.ndarray) -> Integrand:
    """Generic Genz Gaussian exp(-sum a_i^2 (x_i - u_i)^2) with exact value."""
    a = np.asarray(a, np.float64)
    u = np.asarray(u, np.float64)

    def fn(x):
        aa = jnp.asarray(a, x.dtype)[:, None]
        uu = jnp.asarray(u, x.dtype)[:, None]
        return jnp.exp(-jnp.sum((aa * (x - uu)) ** 2, axis=0))

    def exact(d: int) -> float:
        p = 1.0
        for ai, ui in zip(a[:d], u[:d]):
            p *= (
                math.sqrt(math.pi)
                / (2.0 * ai)
                * (math.erf(ai * (1.0 - ui)) + math.erf(ai * ui))
            )
        return p

    return Integrand(name="genz_gaussian", fn=fn, exact=exact)


REGISTRY: dict[str, Integrand] = {
    "f1": Integrand("f1", f1, f1_exact, "oscillatory cos(sum i x_i)"),
    "f2": Integrand("f2", f2, f2_exact, "product peak at x=1/2"),
    "f3": Integrand("f3", f3, f3_exact, "corner peak"),
    "f4": Integrand("f4", f4, f4_exact, "sharp isotropic Gaussian"),
    "f5": Integrand("f5", f5, f5_exact, "C0 kink at x=1/2", smooth=False),
    "f6": Integrand("f6", f6, f6_exact, "discontinuous exponential", smooth=False),
    "f7": Integrand("f7", f7, f7_exact, "(sum x^2)^11 polynomial ridge"),
}


def get(name: str) -> Integrand:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown integrand {name!r}; known: {sorted(REGISTRY)}"
        ) from None
