"""Benchmark integrands from the paper (Section 4) + exact reference values.

All integrands use the SoA convention of the framework: ``f(x)`` receives
coordinates of shape ``(d, N)`` and returns values of shape ``(N,)``.  This
matches the paper's Structure-of-Arrays layout and the TPU lane layout used
by the Pallas kernel (regions on the 128-wide lane axis).

Exact values are analytic (separable products, the Genz corner-peak
inclusion-exclusion formula, and a multinomial DP for f7) over [0, 1]^d.

Beyond the fixed f1..f7 suite, :data:`PARAM_REGISTRY` holds *parameterized
families* ``f(x; theta)`` (Genz Gaussian / product-peak with per-problem
``a``, ``u`` coefficients, monomials) used by the batch quadrature service —
fleets of related integrals differ only in theta, so one compiled program
serves the whole fleet.  Families are reachable from config/CLI through
spec strings (see :func:`from_spec`).
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Integrand:
    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]  # (d, N) -> (N,)
    exact: Callable[[int], float]  # exact integral over [0,1]^d
    description: str = ""
    smooth: bool = True


@dataclasses.dataclass(frozen=True)
class ParamIntegrand:
    """A *family* of integrands ``f(x; theta)`` sharing one domain.

    ``fn`` takes the SoA coordinates ``(d, N)`` plus a theta pytree (a dict of
    per-axis coefficient arrays, see ``theta_fields``) and must be traceable
    with theta as a traced argument — the batch service vmaps over a leading
    problem axis on every theta leaf.  ``exact(d, theta)`` is the analytic
    reference used for validation, ``sample_theta(d, rng)`` draws a random
    problem instance (used by the fleet benchmarks and the serving CLI).
    """

    name: str
    fn: Callable[[jnp.ndarray, Any], jnp.ndarray]  # ((d, N), theta) -> (N,)
    exact: Callable[[int, Any], float]
    sample_theta: Callable[[int, np.random.Generator], dict]
    theta_fields: tuple[str, ...]  # positional order for spec strings
    description: str = ""


def _axis_coeff(x: jnp.ndarray, start: int = 1) -> jnp.ndarray:
    """Per-axis coefficient ``start + axis`` broadcast over ``x``'s shape.

    Generated with a 2-D iota rather than a closed-over ``jnp.arange`` so
    that Pallas kernels which inline the integrand capture no constant
    arrays (pallas_call rejects captured consts).
    """
    return jax.lax.broadcasted_iota(x.dtype, x.shape, 0) + float(start)


# --- f1: oscillatory ---------------------------------------------------------


def f1(x: jnp.ndarray) -> jnp.ndarray:
    i = _axis_coeff(x)
    return jnp.cos(jnp.sum(i * x, axis=0))


def f1_exact(d: int) -> float:
    # cos(sum i x_i) = Re prod_k exp(i k x_k); each 1-D factor integrates to
    # (exp(i k) - 1) / (i k).
    p = complex(1.0, 0.0)
    for k in range(1, d + 1):
        p *= (np.exp(1j * k) - 1.0) / (1j * k)
    return float(p.real)


# --- f2: product peak --------------------------------------------------------

_F2_B2 = 50.0**-2


def f2(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.prod(1.0 / (_F2_B2 + (x - 0.5) ** 2), axis=0)


def f2_exact(d: int) -> float:
    b = 0.02
    one_dim = (2.0 / b) * math.atan(0.5 / b)
    return float(one_dim**d)


# --- f3: corner peak ---------------------------------------------------------


def f3(x: jnp.ndarray) -> jnp.ndarray:
    d = x.shape[0]
    i = _axis_coeff(x)
    return (1.0 + jnp.sum(i * x, axis=0)) ** (-(d + 1.0))


def f3_exact(d: int) -> float:
    # Inclusion-exclusion (Genz): 1/(d! prod c_i) sum_{v in {0,1}^d}
    #   (-1)^|v| / (1 + c . v),   c_i = i.
    c = list(range(1, d + 1))
    total = 0.0
    for mask in range(2**d):
        s = 1.0
        bits = 0
        for i in range(d):
            if (mask >> i) & 1:
                s += c[i]
                bits += 1
        total += (-1.0) ** bits / s
    return float(total / (math.factorial(d) * math.prod(c)))


# --- f4: Gaussian ------------------------------------------------------------


def f4(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp(-(25.0**2) * jnp.sum((x - 0.5) ** 2, axis=0))


def f4_exact(d: int) -> float:
    one_dim = math.sqrt(math.pi) / 25.0 * math.erf(12.5)
    return float(one_dim**d)


# --- f5: C0 (kink) -----------------------------------------------------------


def f5(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp(-10.0 * jnp.sum(jnp.abs(x - 0.5), axis=0))


def f5_exact(d: int) -> float:
    one_dim = 0.2 * (1.0 - math.exp(-5.0))
    return float(one_dim**d)


# --- f6: discontinuous -------------------------------------------------------


def f6(x: jnp.ndarray) -> jnp.ndarray:
    i = _axis_coeff(x)  # 1-based axis index
    cut = (3.0 + i) / 10.0
    inside = jnp.all(x <= cut, axis=0)
    val = jnp.exp(jnp.sum((i + 4.0) * x, axis=0))
    return jnp.where(inside, val, 0.0)


def f6_exact(d: int) -> float:
    p = 1.0
    for i in range(1, d + 1):
        c = i + 4.0
        u = min(1.0, (3.0 + i) / 10.0)
        p *= (math.exp(c * u) - 1.0) / c
    return float(p)


# --- f7: polynomial ridge ----------------------------------------------------

_F7_POW = 11


def f7(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x * x, axis=0) ** _F7_POW


@lru_cache(maxsize=None)
def _f7_dp(j: int, p: int) -> float:
    # F(j, p) = sum_{|k| = p over j dims} p!/prod(k!) prod E[x^{2 k_i}],
    # with E[x^{2k}] = 1/(2k+1) on [0,1].
    if j == 0:
        return 1.0 if p == 0 else 0.0
    total = 0.0
    for k in range(p + 1):
        total += math.comb(p, k) * (1.0 / (2 * k + 1)) * _f7_dp(j - 1, p - k)
    return total


def f7_exact(d: int) -> float:
    return float(_f7_dp(d, _F7_POW))


# --- parameterized families (Genz + monomial) --------------------------------
#
# Each family is an ``f(x; theta)`` over [0,1]^d with an analytic exact value
# per theta.  They back the batch quadrature service (fleets of related
# integrals, one theta per request) and are reachable from config/CLI via
# spec strings parsed by :func:`from_spec`.


def _col(theta_leaf, x) -> jnp.ndarray:
    """Theta leaf (d,) -> column (d, 1) in the coordinate dtype.

    Shapes are static under tracing, so the length check fires at trace
    time: a theta of the wrong length would otherwise silently broadcast
    in the integrand while the analytic ``exact`` truncates to d — two
    different problems agreeing on neither.

    Leaves that already carry a broadcast lane axis — ``(d, 1)`` or
    ``(d, N)`` — pass through unchanged: the Pallas kernel path feeds theta
    as a per-block ``(d, BLOCK)`` operand ref (closures over theta arrays
    are rejected by ``pallas_call`` as captured constants).
    """
    arr = jnp.asarray(theta_leaf, x.dtype)
    if arr.ndim == 2 and arr.shape[0] == x.shape[0] and (
        arr.shape[1] in (1, x.shape[1])
    ):
        return arr
    if arr.shape != (x.shape[0],):
        raise ValueError(
            f"theta leaf has shape {arr.shape}, expected ({x.shape[0]},) "
            f"(or a broadcast ({x.shape[0]}, N)) for a d={x.shape[0]} problem"
        )
    return arr[:, None]


def _genz_gaussian_fn(x: jnp.ndarray, theta) -> jnp.ndarray:
    return jnp.exp(-jnp.sum((_col(theta["a"], x) * (x - _col(theta["u"], x))) ** 2, axis=0))


def _genz_gaussian_exact(d: int, theta) -> float:
    a = np.asarray(theta["a"], np.float64)
    u = np.asarray(theta["u"], np.float64)
    p = 1.0
    for ai, ui in zip(a[:d], u[:d]):
        p *= (
            math.sqrt(math.pi)
            / (2.0 * ai)
            * (math.erf(ai * (1.0 - ui)) + math.erf(ai * ui))
        )
    return float(p)


def _genz_gaussian_sample(d: int, rng: np.random.Generator) -> dict:
    return {"a": rng.uniform(3.0, 10.0, d), "u": rng.uniform(0.2, 0.8, d)}


def _genz_product_peak_fn(x: jnp.ndarray, theta) -> jnp.ndarray:
    a = _col(theta["a"], x)
    u = _col(theta["u"], x)
    return jnp.prod(1.0 / (a**-2 + (x - u) ** 2), axis=0)


def _genz_product_peak_exact(d: int, theta) -> float:
    a = np.asarray(theta["a"], np.float64)
    u = np.asarray(theta["u"], np.float64)
    p = 1.0
    for ai, ui in zip(a[:d], u[:d]):
        p *= ai * (math.atan(ai * (1.0 - ui)) + math.atan(ai * ui))
    return float(p)


def _genz_product_peak_sample(d: int, rng: np.random.Generator) -> dict:
    return {"a": rng.uniform(3.0, 10.0, d), "u": rng.uniform(0.2, 0.8, d)}


def _monomial_fn(x: jnp.ndarray, theta) -> jnp.ndarray:
    return jnp.prod(x ** _col(theta["p"], x), axis=0)


def _monomial_exact(d: int, theta) -> float:
    p = np.asarray(theta["p"], np.float64)
    return float(np.prod(1.0 / (p[:d] + 1.0)))


def _monomial_sample(d: int, rng: np.random.Generator) -> dict:
    return {"p": rng.integers(0, 5, d).astype(np.float64)}


PARAM_REGISTRY: dict[str, ParamIntegrand] = {
    "genz_gaussian": ParamIntegrand(
        "genz_gaussian",
        _genz_gaussian_fn,
        _genz_gaussian_exact,
        _genz_gaussian_sample,
        ("a", "u"),
        "exp(-sum a_i^2 (x_i - u_i)^2)",
    ),
    "genz_product_peak": ParamIntegrand(
        "genz_product_peak",
        _genz_product_peak_fn,
        _genz_product_peak_exact,
        _genz_product_peak_sample,
        ("a", "u"),
        "prod 1 / (a_i^-2 + (x_i - u_i)^2)",
    ),
    "monomial": ParamIntegrand(
        "monomial",
        _monomial_fn,
        _monomial_exact,
        _monomial_sample,
        ("p",),
        "prod x_i^{p_i}",
    ),
}


def get_param(name: str) -> ParamIntegrand:
    try:
        return PARAM_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown integrand family {name!r}; known: {sorted(PARAM_REGISTRY)}"
        ) from None


def bind(family: ParamIntegrand, theta) -> Integrand:
    """Freeze one theta into a plain :class:`Integrand` (serial drivers)."""
    label = ",".join(
        np.array2string(np.asarray(theta[k]), precision=3, separator=",")
        for k in family.theta_fields
    )

    def exact(d: int) -> float:
        for k in family.theta_fields:
            n = np.asarray(theta[k]).shape[0]
            if n != d:
                raise ValueError(
                    f"{family.name}: theta field {k!r} has length {n} "
                    f"but the problem is d={d}"
                )
        return family.exact(d, theta)

    return Integrand(
        name=f"{family.name}:{label}",
        fn=lambda x: family.fn(x, theta),
        exact=exact,
        description=family.description,
    )


def parse_spec(spec: str) -> tuple[ParamIntegrand, dict]:
    """Parse ``family:v,v,..[:v,v,..]`` into ``(family, theta)``.

    One colon-separated group of comma-separated floats per theta field, in
    ``theta_fields`` order — e.g. ``genz_gaussian:5,5:0.3,0.7`` is the d=2
    Gaussian with a=(5,5), u=(0.3,0.7); ``monomial:2,0,3`` is x^2 z^3.
    The single source of truth for the spec grammar — :func:`from_spec`
    and the CLIs both parse through here.
    """
    family_name, _, rest = spec.partition(":")
    family = get_param(family_name)
    if not rest:
        raise ValueError(
            f"family {family_name!r} needs theta groups "
            f"{family.theta_fields} — e.g. {family_name!r} + ':' + "
            "one comma-separated float list per field"
        )
    groups = rest.split(":")
    if len(groups) != len(family.theta_fields):
        raise ValueError(
            f"{spec!r}: expected {len(family.theta_fields)} theta group(s) "
            f"{family.theta_fields}, got {len(groups)}"
        )
    try:
        theta = {
            k: np.asarray([float(v) for v in g.split(",")], np.float64)
            for k, g in zip(family.theta_fields, groups)
        }
    except ValueError:
        raise ValueError(f"{spec!r}: theta groups must be comma-separated floats")
    sizes = {v.shape[0] for v in theta.values()}
    if len(sizes) != 1:
        raise ValueError(f"{spec!r}: theta groups must have equal length, got {sizes}")
    return family, theta


def from_spec(spec: str) -> Integrand:
    """Bind a family spec string (see :func:`parse_spec`) into an Integrand.

    This is what makes the families reachable from ``QuadratureConfig``
    and the CLI, which only carry integrand *names*.
    """
    family, theta = parse_spec(spec)
    return bind(family, theta)


# --- auxiliary factories (public API compatibility wrappers over bind) ------


def make_monomial(powers: tuple[int, ...]) -> Integrand:
    """prod x_i^{p_i} with exact integral prod 1/(p_i + 1) over [0,1]^d."""
    return bind(
        PARAM_REGISTRY["monomial"], {"p": np.asarray(powers, np.float64)}
    )


def make_genz_gaussian(a: np.ndarray, u: np.ndarray) -> Integrand:
    """Generic Genz Gaussian exp(-sum a_i^2 (x_i - u_i)^2) with exact value."""
    return bind(
        PARAM_REGISTRY["genz_gaussian"],
        {"a": np.asarray(a, np.float64), "u": np.asarray(u, np.float64)},
    )


REGISTRY: dict[str, Integrand] = {
    "f1": Integrand("f1", f1, f1_exact, "oscillatory cos(sum i x_i)"),
    "f2": Integrand("f2", f2, f2_exact, "product peak at x=1/2"),
    "f3": Integrand("f3", f3, f3_exact, "corner peak"),
    "f4": Integrand("f4", f4, f4_exact, "sharp isotropic Gaussian"),
    "f5": Integrand("f5", f5, f5_exact, "C0 kink at x=1/2", smooth=False),
    "f6": Integrand("f6", f6, f6_exact, "discontinuous exponential", smooth=False),
    "f7": Integrand("f7", f7, f7_exact, "(sum x^2)^11 polynomial ridge"),
}


def get(name: str) -> Integrand:
    """Resolve an integrand name: fixed registry entry or family spec string."""
    if name in REGISTRY:
        return REGISTRY[name]
    if ":" in name:
        return from_spec(name)
    raise KeyError(
        f"unknown integrand {name!r}; known: {sorted(REGISTRY)} plus "
        f"family specs {sorted(PARAM_REGISTRY)} (e.g. 'genz_gaussian:5,5:0.3,0.7')"
    )
