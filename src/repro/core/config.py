"""Configuration for the adaptive quadrature engine (single- and multi-device)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QuadratureConfig:
    """Static configuration of one integration problem.

    Everything here is compile-time static; the dynamic problem state lives in
    :class:`repro.core.region_store.RegionState`.
    """

    d: int
    integrand: str = "f4"
    rel_tol: float = 1e-8
    abs_tol: float = 1e-16  # the paper's floor: eps <= max(1e-16, |I| tau_rel)
    # --- backend selection ----------------------------------------------------
    # "cubature" runs the deterministic adaptive-subdivision engine (the
    # paper's reproduction); "vegas" runs the adaptive importance-sampling
    # Monte Carlo subsystem (repro.mc) whose cost is dimension-independent
    # per sample — the only feasible regime once the Genz-Malik point count
    # (2^d + 2d^2 + 2d + 1 per region) explodes; "auto" picks vegas at
    # d >= auto_backend_dim and cubature below it.
    backend: str = "cubature"  # "cubature" | "vegas" | "auto"
    auto_backend_dim: int = 9  # "auto" crossover dimension (see DESIGN.md §7)
    capacity: int = 1 << 14  # fixed SoA region-store capacity per device
    # Initial uniform partition size (power of two).  0 = auto: 2^d clipped to
    # capacity/4 — splitting EVERY axis at least once is required so that a
    # sharp feature at the domain centre (e.g. f4's Gaussian, which sits on
    # the corner of every octant) is bracketed by rule nodes; with fewer
    # boxes the fully-symmetric rule can be structurally blind to it and
    # converge to a wrong answer (regression-tested).
    n_init: int = 0
    max_iters: int = 600
    classifier: str = "robust"  # "robust" (ours) | "aggressive" (PAGANI-like)
    rule: str = "genz_malik"  # "genz_malik" | "gauss_kronrod"
    use_kernel: bool = False  # Pallas GM kernel (interpret on CPU) vs pure jnp
    interpret: bool = True  # Pallas interpret mode (CPU validation); False on TPU
    block_regions: int = 0  # kernel lanes per block; 0 = kernels.ops default
    dtype: str = "float64"
    # --- active-window evaluation --------------------------------------------
    # The compaction invariant (see region_store / split docstrings) keeps all
    # active regions contiguous at the front of the store, so the rule only
    # needs to be evaluated on the leading window of the SoA arrays.  Window
    # sizes are drawn from a geometric ladder of powers of two so the number
    # of distinct compiled shapes stays at log2(capacity / eval_window_min).
    eval_window: bool = True  # evaluate only the leading active window
    eval_window_min: int = 256  # smallest ladder bucket (power of two)
    # Window the *advance* stage too (classify thresholding, global-estimate
    # reductions, and the sort-based split/compact): the argsort and every
    # gather/scatter run on the smallest ladder rung covering
    # min(2 * n_active, capacity) — splitting can double the population, and
    # the capacity-pressure scalars (the 3C/4 forced-finalise limit, the
    # split budget k = min(n_act, C - n_act)) stay defined against the full
    # capacity, so trajectories are bit-identical to the full-capacity
    # advance in every regime (see DESIGN.md §3).  Shares eval_window_min as
    # the smallest rung.
    advance_window: bool = True
    # --- batch service -------------------------------------------------------
    # The continuous-batching engine (repro.service) runs ``batch_slots``
    # independent problems of this config's shape in lockstep under vmap; a
    # slot freed by a converged problem is refilled from the request queue
    # every ``admit_every`` iterations.
    batch_slots: int = 16
    admit_every: int = 1
    # An overflowed slot may keep refining this many further iterations
    # before the scheduler evicts it with status "capacity".  The serial
    # driver grinds past capacity pressure and often still converges
    # (children that don't fit are dropped, the survivors keep shrinking
    # the error), so evicting at *first* overflow would both break parity
    # with `integrate` and throw away near-finished work; the grace period
    # keeps parity for transiently-saturated problems while still freeing
    # the slot from hopeless ones long before max_iters.
    evict_patience: int = 16
    # --- sharded service mesh + problem-level rebalancing ---------------------
    # The batch service shards its leading problem axis over a device mesh:
    # each device owns a contiguous block of batch_slots / n_devices slots and
    # runs the vmapped windowed step locally.  ``service_devices`` picks the
    # mesh size (1 = single-device legacy path, 0 = every visible device);
    # an explicit mesh/devices argument to BatchEngine overrides it.
    service_devices: int = 1
    # When a device's live slots drain (converged problems collected, queue
    # dry), whole *problems* migrate from its cyclic ring partner — the same
    # static-schedule ppermute pairing ``redistribution.redistribute`` uses
    # for regions, lifted to the problem level.  "off" disables migration;
    # ``rebalance_cap`` bounds problems moved per pair per iteration (the
    # payload is a full slot: region store + theta + tolerances).
    rebalance: str = "ring"
    rebalance_cap: int = 1
    # --- distributed ---------------------------------------------------------
    message_cap: int = 512  # max regions per transfer (paper default)
    init_regions_per_device: int = 8  # paper: 8 subdomains per rank at startup
    redistribution: str = "ring"  # any value != "off" enables the static
    #   ring-schedule round-robin policy ("xor" accepted as a legacy alias)
    sync_every: int = 4  # iterations fused per dispatch in integrate_distributed;
    #   convergence is checked on device each iteration, the host only syncs
    #   (and reads back the per-iteration metrics) every sync_every steps
    # --- numerical guards (Gander-Gautschi style) -----------------------------
    min_width_frac: float = 1e-10  # halfwidth floor relative to domain width
    noise_mult: float = 50.0  # round-off noise floor multiplier
    # A region may not be FINALISED before it has been bisected this many
    # times per axis (on average, by volume): pre-asymptotic rule estimates
    # on smooth peaked integrands (f3) can coincidentally agree while all
    # biased the same way, so the summed claimed error understates the true
    # error ~10x at loose tolerances; two confirmed halvings per axis puts
    # the embedded differences in the asymptotic regime first.  Convergence
    # itself needs no finalisation, so cheap problems are unaffected.
    min_depth_per_axis: int = 2
    # --- VEGAS backend (repro.mc) ---------------------------------------------
    # One MC iteration draws ``mc_samples`` stratified samples through the
    # per-axis importance grid (``mc_bins`` bins per axis), accumulates
    # per-stratum mean/variance, and refines grid + per-stratum sample
    # counts.  The sample stream is generated and reduced in ``mc_shards``
    # fixed independent shards — the unit of multi-device work division —
    # so estimates are bit-identical at any device count dividing it.
    mc_samples: int = 8192  # samples per iteration (divisible by mc_shards)
    mc_bins: int = 64  # importance-grid bins per axis
    mc_shards: int = 8  # static reduction shards (>= and divisible by devices)
    mc_warmup: int = 5  # adapt-only iterations excluded from the estimator
    mc_max_iters: int = 100  # MC iteration cap (cubature keeps max_iters)
    mc_alpha: float = 0.75  # grid-refinement damping exponent (Lepage alpha)
    mc_beta: float = 0.75  # stratification count-adaptation exponent (VEGAS+)
    mc_min_per_cube: int = 4  # floor on samples per stratification hypercube
    mc_seed: int = 0  # PRNG seed: same seed -> bit-identical estimate
    # --- domain (defaults to the unit cube) -----------------------------------
    domain_lo: tuple = ()
    domain_hi: tuple = ()

    def lo(self) -> tuple:
        return self.domain_lo if self.domain_lo else (0.0,) * self.d

    def hi(self) -> tuple:
        return self.domain_hi if self.domain_hi else (1.0,) * self.d

    def resolved_backend(self) -> str:
        """Concrete backend for this problem ("auto" resolves on dimension)."""
        if self.backend == "auto":
            return "vegas" if self.d >= self.auto_backend_dim else "cubature"
        return self.backend

    def resolved_n_init(self) -> int:
        if self.n_init:
            return self.n_init
        return max(8, min(2**self.d, self.capacity // 4, 1 << 12))

    def validate(self) -> "QuadratureConfig":
        if self.d < 1:
            raise ValueError("d must be >= 1")
        if self.capacity & (self.capacity - 1):
            raise ValueError("capacity must be a power of two")
        if self.n_init & (self.n_init - 1):
            raise ValueError("n_init must be a power of two (or 0 = auto)")
        if self.resolved_n_init() > self.capacity // 2:
            raise ValueError("n_init must leave room to split (<= capacity/2)")
        if self.classifier not in ("robust", "aggressive"):
            raise ValueError(f"unknown classifier {self.classifier!r}")
        if self.rule not in ("genz_malik", "gauss_kronrod"):
            raise ValueError(f"unknown rule {self.rule!r}")
        if self.eval_window_min < 1 or (
            self.eval_window_min & (self.eval_window_min - 1)
        ):
            raise ValueError("eval_window_min must be a positive power of two")
        if self.block_regions < 0 or (
            self.block_regions & (self.block_regions - 1)
        ):
            raise ValueError("block_regions must be a power of two (or 0 = default)")
        if self.sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.admit_every < 1:
            raise ValueError("admit_every must be >= 1")
        if self.evict_patience < 0:
            raise ValueError("evict_patience must be >= 0")
        if self.service_devices < 0:
            raise ValueError("service_devices must be >= 0 (0 = all devices)")
        if self.rebalance not in ("ring", "off"):
            raise ValueError(f"unknown rebalance policy {self.rebalance!r}")
        if self.rebalance_cap < 1:
            raise ValueError("rebalance_cap must be >= 1")
        if self.backend not in ("cubature", "vegas", "auto"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.auto_backend_dim < 1:
            raise ValueError("auto_backend_dim must be >= 1")
        if self.mc_shards < 1:
            raise ValueError("mc_shards must be >= 1")
        if self.mc_samples < 16 or self.mc_samples % self.mc_shards:
            raise ValueError(
                "mc_samples must be >= 16 and divisible by mc_shards "
                f"(got mc_samples={self.mc_samples}, mc_shards={self.mc_shards})"
            )
        if self.mc_bins < 2:
            raise ValueError("mc_bins must be >= 2")
        if self.mc_warmup < 1:
            raise ValueError("mc_warmup must be >= 1 (the estimator needs an "
                             "adapted grid before accumulating)")
        if self.mc_max_iters <= self.mc_warmup:
            raise ValueError("mc_max_iters must exceed mc_warmup")
        if self.mc_min_per_cube < 2:
            raise ValueError("mc_min_per_cube must be >= 2 (per-stratum "
                             "variance needs two samples)")
        if self.mc_samples < 2 * self.mc_min_per_cube:
            raise ValueError("mc_samples must cover 2 * mc_min_per_cube")
        if self.mc_alpha < 0 or self.mc_beta < 0:
            raise ValueError("mc_alpha / mc_beta must be >= 0")
        if len(self.domain_lo) not in (0, self.d):
            raise ValueError("domain_lo must be empty or length d")
        if len(self.domain_hi) not in (0, self.d):
            raise ValueError("domain_hi must be empty or length d")
        return self
