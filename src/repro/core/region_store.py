"""Fixed-capacity Structure-of-Arrays region store.

XLA requires static shapes, so the dynamic region list of CPU adaptive codes
becomes a fixed-capacity SoA store plus an ``active`` mask — the same design
PAGANI uses on GPU (the paper keeps "all subregion data resident on the
device" in SoA layout; here the arrays additionally live in a jit-compiled
program so the whole iteration is one XLA module).

Slot discipline maintained by ``repro.core.split.classify_split_compact``:
active regions are compacted to the front and sorted by descending error
estimate; finalised regions are folded into scalar accumulators and their
slots freed.

**Active-window invariant.**  Every operation that mutates the region
population keeps the active slots contiguous in ``[0, n_active)``:
``init_state`` fills the leading slots, ``classify_split_compact`` compacts
survivors to the front and appends children directly after them, and
``redistribution.redistribute`` only retires or splices the tail of the
occupied block.  The adaptive drivers exploit this to run the *whole
iteration* — rule evaluation, classification/global reductions, and the
sort-based split/compact advance — on a leading *window* of the SoA arrays
sized from a geometric ladder (:func:`window_ladder` / :func:`select_window`)
instead of all ``capacity`` slots, so per-iteration cost scales with the live
population (the advance stage needs ``window >= min(2 * n_active, capacity)``
because splitting can double the population; see DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "centers",
        "halfw",
        "est",
        "err",
        "axis",
        "active",
        "fresh",
        "fin_integral",
        "fin_error",
        "n_evals",
        "it",
        "overflowed",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class RegionState:
    """One device's region population + finalised accumulators."""

    centers: jnp.ndarray  # (C, d)
    halfw: jnp.ndarray  # (C, d)
    est: jnp.ndarray  # (C,)   degree-7 estimate
    err: jnp.ndarray  # (C,)   heuristic error estimate
    axis: jnp.ndarray  # (C,)   int32 split axis
    active: jnp.ndarray  # (C,)   bool
    fresh: jnp.ndarray  # (C,)   bool — needs (re-)evaluation
    fin_integral: jnp.ndarray  # ()  accumulated finalised integral
    fin_error: jnp.ndarray  # ()  accumulated finalised error
    n_evals: jnp.ndarray  # ()  float64 integrand-evaluation counter
    it: jnp.ndarray  # ()  int32 iteration counter
    overflowed: jnp.ndarray  # () bool — capacity pressure was ever hit

    @property
    def capacity(self) -> int:
        return self.centers.shape[0]

    @property
    def d(self) -> int:
        return self.centers.shape[1]

    def n_active(self) -> jnp.ndarray:
        return jnp.sum(self.active)

    def global_estimates(
        self, window: int | None = None
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(integral, error) combining finalised + active contributions.

        ``window`` reduces over the leading rows only — exact whenever every
        active slot sits inside the window, which the active-window invariant
        guarantees for any ``window >= n_active`` (the masked tail contributes
        exact zeros, so the windowed and full reductions agree bitwise).
        """
        act = self.active if window is None else self.active[:window]
        est = self.est if window is None else self.est[:window]
        err = self.err if window is None else self.err[:window]
        integral = self.fin_integral + jnp.sum(jnp.where(act, est, 0.0))
        error = self.fin_error + jnp.sum(jnp.where(act, err, 0.0))
        return integral, error


def empty_state(capacity: int, d: int, dtype) -> RegionState:
    z = jnp.zeros
    return RegionState(
        centers=z((capacity, d), dtype),
        halfw=z((capacity, d), dtype),
        est=z((capacity,), dtype),
        err=z((capacity,), dtype),
        axis=z((capacity,), jnp.int32),
        active=z((capacity,), bool),
        fresh=z((capacity,), bool),
        fin_integral=jnp.asarray(0.0, dtype),
        fin_error=jnp.asarray(0.0, dtype),
        n_evals=jnp.asarray(0.0, dtype),
        it=jnp.asarray(0, jnp.int32),
        overflowed=jnp.asarray(False, bool),
    )


def uniform_partition(
    lo: np.ndarray, hi: np.ndarray, n_boxes: int
) -> tuple[np.ndarray, np.ndarray]:
    """Bisect [lo, hi] into ``n_boxes`` (power of two) equal boxes.

    Axes are cycled in round-robin order, so the partition stays as cubic as
    possible — this is the paper's "initial uniform partition".
    Returns (centers, halfw) as float64 arrays of shape (n_boxes, d).
    """
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    d = lo.shape[0]
    assert n_boxes & (n_boxes - 1) == 0, "n_boxes must be a power of two"
    boxes = [(lo.copy(), hi.copy())]
    level = 0
    while len(boxes) < n_boxes:
        axis = level % d
        nxt = []
        for blo, bhi in boxes:
            mid = 0.5 * (blo[axis] + bhi[axis])
            left_hi = bhi.copy()
            left_hi[axis] = mid
            right_lo = blo.copy()
            right_lo[axis] = mid
            nxt.append((blo, left_hi))
            nxt.append((right_lo, bhi))
        boxes = nxt
        level += 1
    centers = np.stack([0.5 * (b[0] + b[1]) for b in boxes])
    halfw = np.stack([0.5 * (b[1] - b[0]) for b in boxes])
    return centers, halfw


def init_state(
    capacity: int,
    lo: np.ndarray,
    hi: np.ndarray,
    n_init: int,
    dtype,
) -> RegionState:
    """Fresh state holding the initial uniform partition."""
    lo = np.asarray(lo, np.float64)
    d = lo.shape[0]
    centers, halfw = uniform_partition(lo, hi, n_init)
    st = empty_state(capacity, d, dtype)
    st = dataclasses.replace(
        st,
        centers=st.centers.at[:n_init].set(jnp.asarray(centers, dtype)),
        halfw=st.halfw.at[:n_init].set(jnp.asarray(halfw, dtype)),
        active=st.active.at[:n_init].set(True),
        fresh=st.fresh.at[:n_init].set(True),
    )
    return st


def stacked_empty_state(n: int, capacity: int, d: int, dtype) -> RegionState:
    """Empty store with a leading ``(n,)`` axis on every leaf.

    Used by the batch service (one sub-store per problem slot); each slice
    along the leading axis independently satisfies the active-window
    invariant.
    """
    one = empty_state(capacity, d, dtype)
    return jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)


def write_slot(
    stacked: RegionState, slot, single: RegionState, mode: str | None = None
) -> RegionState:
    """Overwrite slice ``slot`` of a stacked store with a single-store state.

    Jit-safe with a traced ``slot`` index — the batch service uses this to
    splice a fresh initial partition into a slot freed by a converged
    problem without recompiling per slot.  ``mode`` is forwarded to the
    scatter (the sharded service writes with ``mode="drop"`` and an
    out-of-bounds index on every device but the slot's owner).
    """
    return jax.tree.map(
        lambda dst, src: dst.at[slot].set(src, mode=mode), stacked, single
    )


def window_ladder(capacity: int, min_window: int = 256) -> tuple[int, ...]:
    """Geometric ladder of power-of-two eval-window sizes up to ``capacity``.

    Each rung doubles the previous one, so at most
    ``log2(capacity / min_window) + 1`` distinct window shapes (and therefore
    jit-compiled eval variants) ever exist.  The top rung is always exactly
    ``capacity`` so a full store degrades to the legacy full-capacity path.
    """
    if capacity < 1 or capacity & (capacity - 1):
        raise ValueError("capacity must be a positive power of two")
    w = max(1, min(min_window, capacity))
    w = 1 << (w - 1).bit_length()  # round up to a power of two
    ladder = []
    while w < capacity:
        ladder.append(w)
        w <<= 1
    ladder.append(capacity)
    return tuple(ladder)


def rung_index(rungs: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Index of the smallest ladder rung covering ``n`` (clamped to the top).

    The device-side rung pick, shared by every ``lax.switch``-dispatched
    windowed eval (single-device, distributed, batch service) so all paths
    agree bit-for-bit with the host-side :func:`select_window`.
    """
    return jnp.minimum(jnp.searchsorted(rungs, n), rungs.shape[0] - 1)


def select_window(ladder: tuple[int, ...], n_active: int) -> int:
    """Smallest ladder rung that covers ``n_active`` contiguous rows.

    Host-side mirror of the device-side rung choice in
    ``adaptive.make_switched_eval_step`` — both are left-searchsorted, so the
    host- and device-driven loops pick identical windows for the same count.
    ``n_active == 0`` selects the smallest rung (the drivers still dispatch
    one eval before observing the empty population; keep it cheap).
    """
    ix = int(np.searchsorted(np.asarray(ladder), n_active, side="left"))
    return ladder[min(ix, len(ladder) - 1)]


def check_invariants(state: RegionState, lo, hi, atol: float = 1e-12) -> None:
    """Host-side structural checks (used by tests, not in the hot path)."""
    c = np.asarray(state.centers)
    h = np.asarray(state.halfw)
    act = np.asarray(state.active)
    assert np.all(h[act] > 0), "active region with non-positive halfwidth"
    assert np.all(c[act] - h[act] >= np.asarray(lo) - atol), "region below domain"
    assert np.all(c[act] + h[act] <= np.asarray(hi) + atol), "region above domain"
    fresh = np.asarray(state.fresh)
    assert not np.any(fresh & ~act), "fresh flag set on inactive slot"
    # active-window invariant: actives contiguous at the front of the store
    assert not np.any(act[int(act.sum()) :]), "active slots not contiguous"
