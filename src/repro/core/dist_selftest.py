"""Self-contained multi-device self-test, run in a subprocess by the tests.

Must be launched as ``python -m repro.core.dist_selftest [n_devices]`` —
sets XLA_FLAGS before importing jax, runs distributed-vs-single checks, and
prints one JSON blob on the last line.
"""

import json
import os
import sys


def main() -> None:
    n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import integrands
    from repro.core.adaptive import integrate
    from repro.core.config import QuadratureConfig
    from repro.core.distributed import integrate_distributed

    assert len(jax.devices()) == n_dev, jax.devices()

    out = {"n_devices": n_dev, "cases": []}
    cases = [
        ("f4", 4, 1e-6),
        ("f2", 3, 1e-6),
        ("f6", 3, 1e-5),
        ("f1", 4, 1e-6),
    ]
    for name, d, tol in cases:
        cfg = QuadratureConfig(
            d=d, integrand=name, rel_tol=tol, capacity=1 << 13, max_iters=200
        )
        single = integrate(cfg)
        dist = integrate_distributed(cfg)
        off = integrate_distributed(
            QuadratureConfig(**{**cfg.__dict__, "redistribution": "off"})
        )
        exact = integrands.get(name).exact(d)
        out["cases"].append(
            {
                "integrand": name,
                "d": d,
                "rel_tol": tol,
                "exact": exact,
                "single": {"I": single.integral, "status": single.status},
                "dist": {
                    "I": dist.integral,
                    "eps": dist.error,
                    "status": dist.status,
                    "iters": dist.iterations,
                    "n_evals": dist.n_evals,
                    "mean_imbalance": dist.mean_imbalance(),
                    "evals_per_device": dist.evals_per_device.tolist(),
                },
                "dist_noredist": {
                    "I": off.integral,
                    "status": off.status,
                    "mean_imbalance": off.mean_imbalance(),
                },
            }
        )

    print("RESULT_JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
