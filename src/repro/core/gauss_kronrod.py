"""Tensor-product (G7, K15) Gauss-Kronrod rule (paper §3, single-device only).

The 15-point Kronrod extension of the 7-point Gauss rule is tensorised over
``d`` axes.  The Gauss nodes are a subset of the Kronrod nodes, so the whole
embedded family is evaluated from one streaming pass over the 15^d grid —
nothing of size 15^d is ever materialised (nodes are decoded from a flat
index in fixed-size chunks).  Cost grows as 15^d, which is why the paper
limits this rule to low/moderate dimension (prohibitive for d >= 7).

Error estimate: |K - G| over the full tensor grid.  Axis selection: the axis
``i`` maximising |K - G_i| where G_i applies the Gauss weights along axis i
and Kronrod weights along the others (a per-axis smoothness probe that falls
out of the same streaming pass for free).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# QUADPACK 15-point Kronrod nodes/weights on [-1, 1]; Gauss-7 is embedded at
# the odd positions.  Symmetric: we store the full 15 for simple indexing.
_XK_HALF = np.array(
    [
        0.991455371120813,
        0.949107912342759,
        0.864864423359769,
        0.741531185599394,
        0.586087235467691,
        0.405845151377397,
        0.207784955007898,
        0.0,
    ]
)
_WK_HALF = np.array(
    [
        0.022935322010529,
        0.063092092629979,
        0.104790010322250,
        0.140653259715525,
        0.169004726639267,
        0.190350578064785,
        0.204432940075298,
        0.209482141084728,
    ]
)
_WG_HALF = np.array(  # Gauss-7 weights at Kronrod positions 1,3,5,7 (0-based)
    [
        0.0,
        0.129484966168870,
        0.0,
        0.279705391489277,
        0.0,
        0.381830050505119,
        0.0,
        0.417959183673469,
    ]
)

XK = np.concatenate([-_XK_HALF[:-1], _XK_HALF[::-1]])  # 15 ascending nodes
WK = np.concatenate([_WK_HALF[:-1], _WK_HALF[::-1]])
WG = np.concatenate([_WG_HALF[:-1], _WG_HALF[::-1]])

N_1D = 15


def n_nodes(d: int) -> int:
    return N_1D**d


def gk_eval_batch(f, centers: jnp.ndarray, halfw: jnp.ndarray, chunk: int = 512):
    """Evaluate the tensor GK rule on a batch of regions.

    Args:
      f: integrand mapping (d, N) -> (N,).
      centers, halfw: (B, d).
      chunk: nodes processed per streaming step.

    Returns:
      (i_k, i_g, axis_disc): Kronrod and Gauss estimates (B,), plus the
      per-axis |K - G_i| discrepancies (B, d) used for axis selection.
    """
    dtype = centers.dtype
    b, d = centers.shape
    total = N_1D**d
    n_chunks = -(-total // chunk)

    xk = jnp.asarray(XK, dtype)
    wk = jnp.asarray(WK, dtype)
    wg = jnp.asarray(WG, dtype)

    ct = centers.T  # (d, B)
    ht = halfw.T

    def body(c_idx, carry):
        s_k, s_g, s_gi = carry
        flat = c_idx * chunk + jnp.arange(chunk)  # (chunk,)
        valid = (flat < total).astype(dtype)
        flat = jnp.minimum(flat, total - 1)
        # decode base-15 digits: digit[i] for axis i
        digits = []
        rem = flat
        for _ in range(d):
            digits.append(rem % N_1D)
            rem = rem // N_1D
        digits = jnp.stack(digits, axis=0)  # (d, chunk)

        nodes = xk[digits]  # (d, chunk)
        wk_ax = wk[digits]  # (d, chunk)
        wg_ax = wg[digits]
        w_k = jnp.prod(wk_ax, axis=0) * valid  # (chunk,)
        w_g = jnp.prod(wg_ax, axis=0) * valid
        # per-axis: Gauss along axis i, Kronrod elsewhere
        ratio = wg_ax / wk_ax  # (d, chunk); wk never zero
        w_gi = w_k[None, :] * ratio  # (d, chunk)

        # coordinates: (d, B, chunk)
        x = ct[:, :, None] + ht[:, :, None] * nodes[:, None, :]
        vals = f(x.reshape(d, b * chunk)).reshape(b, chunk)

        s_k = s_k + vals @ w_k
        s_g = s_g + vals @ w_g
        s_gi = s_gi + jnp.einsum("bc,dc->bd", vals, w_gi)
        return s_k, s_g, s_gi

    init = (
        jnp.zeros((b,), dtype),
        jnp.zeros((b,), dtype),
        jnp.zeros((b, d), dtype),
    )
    s_k, s_g, s_gi = jax.lax.fori_loop(0, n_chunks, body, init)

    scale = jnp.prod(ht, axis=0)  # (B,)
    i_k = scale * s_k
    i_g = scale * s_g
    axis_disc = jnp.abs(scale[:, None] * (s_gi - s_k[:, None]))
    return i_k, i_g, axis_disc
