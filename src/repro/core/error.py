"""Two-level heuristic error estimator tailored to the embedded GM family.

Follows the philosophy of Berntsen-Espelid-Genz [2]: compare two levels of
embedded differences,

    n1 = |I7 - I5|   (behaves like a degree-5 null rule)
    n2 = |I5 - I3|   (degree-3 level)

When the integrand is *smooth and resolved* on the subregion, the ratio
``r = n1/n2`` is small and the true error of I7 is far below n1; we then
extrapolate the estimate down by ``sqrt(2 r)``.

Two gates keep the extrapolation honest (both regression-tested):

- ratio gate ``r < 1/8``: at the gate boundary the shrink factor is at most
  ``sqrt(2/8) = 1/2``;
- smoothness gate: the per-axis fourth divided differences must be small
  relative to the mean integrand magnitude ``|I7|/vol``.  On boxes straddling
  a discontinuity (f6) or an unresolved oscillation (f1) the fourth
  differences are O(f), and extrapolating there systematically understates
  the error: I7 and I5 share all their nodes, so their difference measures
  only *weight* disagreement and misses the common sampling bias.  Without
  this gate the solver declares convergence on f6 with a true error ~40x the
  claimed estimate; with it, claimed >= true across the whole f1..f7 suite.

A round-off noise floor (Gander-Gautschi style guard, [4]) prevents
over-refinement once differences reach machine noise.
"""

from __future__ import annotations

import jax.numpy as jnp

_R_CRIT = 0.125
_SMOOTH_FRAC = 0.05  # fourth differences below 5% of mean |f| => smooth


def two_level_error(
    i7: jnp.ndarray,
    i5: jnp.ndarray,
    i3: jnp.ndarray,
    vol: jnp.ndarray,
    max_fourth_diff: jnp.ndarray,
    noise_mult: float,
) -> jnp.ndarray:
    """Per-region heuristic error estimate.

    Args:
      i7, i5, i3: embedded rule estimates, shape (B,).
      vol: region volumes (B,).
      max_fourth_diff: max over axes of the fourth divided differences (B,) —
        raw function-value scale, not volume-scaled.
      noise_mult: multiplier on machine epsilon for the noise floor.
    """
    eps = jnp.finfo(i7.dtype).eps
    tiny = jnp.finfo(i7.dtype).tiny
    n1 = jnp.abs(i7 - i5)
    n2 = jnp.abs(i5 - i3)

    r = n1 / jnp.maximum(n2, tiny)
    shrink = jnp.minimum(jnp.sqrt(2.0 * r), 1.0)
    f_mean = jnp.abs(i7) / jnp.maximum(vol, tiny)
    smooth = max_fourth_diff <= _SMOOTH_FRAC * f_mean
    asymptotic = (n2 > tiny) & (r < _R_CRIT) & smooth
    err = jnp.where(asymptotic, n1 * shrink, n1)

    # Round-off noise floor: differences below eps * local magnitude are
    # numerical noise, not signal; clamp so the classifier finalises them.
    noise = noise_mult * eps * (jnp.abs(i7) + vol * f_mean)
    return jnp.maximum(err, noise)
