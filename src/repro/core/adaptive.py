"""Single-device batch-adaptive quadrature driver (paper Fig. 1a).

Unlike heap-driven h-adaptivity, *every* region whose error contribution is
non-negligible is refined each iteration (PAGANI-style batch adaptivity).
Two drivers are provided:

- :func:`integrate` — host-driven loop around a jitted step, one scalar sync
  per iteration (mirrors the paper's workflow, and is what the distributed
  driver extends);
- :func:`integrate_device` — fully device-resident ``lax.while_loop`` with no
  host synchronisation at all (TPU-native improvement; the convergence check
  runs on device, which is what the paper's global sync point becomes when
  the whole solver is one XLA program).

Both drivers evaluate the rule over an *active window* — the leading slice of
the compacted store sized from a geometric ladder (see
``region_store.window_ladder``) — so per-iteration cost scales with the live
region population rather than store capacity.  The host driver picks the
window from the active count it already syncs (one cached jit per rung); the
device driver selects the statically-shaped branch with ``lax.switch``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import region_store
from repro.core.classify import classify, error_budget, nonfinite_mask
from repro.core.config import QuadratureConfig
from repro.core.integrands import get as get_integrand
from repro.core.region_store import RegionState
from repro.core.rules import make_rule
from repro.core.split import classify_split_compact
from repro.telemetry import NULL


@dataclasses.dataclass
class AdaptiveResult:
    integral: float
    error: float  # global error estimate (the paper's epsilon)
    status: str  # converged | max_iters | no_active | capacity
    iterations: int
    n_evals: float
    n_active: int
    overflowed: bool

    def summary(self) -> str:
        return (
            f"I={self.integral:.15e} eps={self.error:.3e} [{self.status}] "
            f"iters={self.iterations} evals={self.n_evals:.3g}"
        )


def make_eval_step(
    cfg: QuadratureConfig, rule, window: Optional[int] = None
) -> Callable[[RegionState], RegionState]:
    """Evaluate fresh regions, update per-region estimates + eval counter.

    ``window`` restricts the rule evaluation to the leading ``window`` rows
    of the store.  By the active-window invariant (region_store docstring)
    every active — hence every fresh — region lives in ``[0, n_active)``, so
    any ``window >= n_active`` produces bit-identical results to the legacy
    full-capacity evaluation while doing ``window / capacity`` of the work.
    ``None`` evaluates the full store.
    """

    def eval_step(state: RegionState) -> RegionState:
        w = state.capacity if window is None else min(window, state.capacity)
        need = state.active[:w] & state.fresh[:w]
        est, err, axis = rule.eval_batch(state.centers[:w], state.halfw[:w])
        return dataclasses.replace(
            state,
            est=state.est.at[:w].set(jnp.where(need, est, state.est[:w])),
            err=state.err.at[:w].set(jnp.where(need, err, state.err[:w])),
            axis=state.axis.at[:w].set(jnp.where(need, axis, state.axis[:w])),
            fresh=jnp.zeros_like(state.fresh),
            n_evals=state.n_evals
            + jnp.sum(need).astype(state.n_evals.dtype) * rule.n_evals_per_region,
        )

    return eval_step


def make_switched_eval_step(
    cfg: QuadratureConfig, rule
) -> Callable[[RegionState], RegionState]:
    """Device-resident windowed evaluation: ``lax.switch`` over the ladder.

    For drivers that never sync the active count to the host
    (:func:`integrate_device`, the distributed per-device step) the window is
    chosen on device: the active count indexes the smallest ladder rung that
    covers the population and dispatches the matching statically-shaped
    branch.
    """
    if not cfg.eval_window:
        return make_eval_step(cfg, rule)
    ladder = eval_ladder(cfg)
    branches = [make_eval_step(cfg, rule, window=w) for w in ladder]
    rungs = jnp.asarray(ladder, jnp.int32)

    def eval_step(state: RegionState) -> RegionState:
        n = jnp.sum(state.active).astype(jnp.int32)
        ix = region_store.rung_index(rungs, n)
        return jax.lax.switch(ix, branches, state)

    return eval_step


def eval_ladder(cfg: QuadratureConfig) -> tuple[int, ...]:
    """The eval-window ladder, or the single full-capacity rung when the
    active-window path is disabled — shared by every driver so they can
    never disagree on the available window shapes."""
    if not cfg.eval_window:
        return (cfg.capacity,)
    return region_store.window_ladder(cfg.capacity, cfg.eval_window_min)


def advance_ladder(cfg: QuadratureConfig) -> tuple[int, ...]:
    """The advance-window ladder, gated by ``cfg.advance_window``.

    Advance rungs are picked to cover ``min(2 * n_active, capacity)`` — see
    :func:`advance_target` — because splitting can double the live population
    and capacity pressure needs the full-capacity rung for its
    forced-finalise semantics.

    The ladder is the *coarse* (x4-geometric) sub-ladder of the eval ladder,
    top rung always exactly ``capacity``: any rung covering the target is
    bit-identical, and the advance at a rung costs far less than the eval at
    the same rung, so fine granularity buys almost no runtime — while every
    extra rung is one more traced-and-compiled branch in the ``lax.switch``
    drivers (device-resident loop, vmapped batch engine), where compile time
    is a real cost for short-lived engines.
    """
    if not cfg.advance_window:
        return (cfg.capacity,)
    full = region_store.window_ladder(cfg.capacity, cfg.eval_window_min)
    return tuple(sorted(full[::-2]))  # top-down every other rung, keeps C


def advance_target(n_active, capacity: int):
    """Row count the advance window must cover for an ``n_active`` population.

    Post-split the population is ``n_act + k`` with
    ``k = min(n_act, C - n_act)``, i.e. at most ``min(2 * n_active, C)``; and
    whenever the capacity-pressure path (forced finalise at ``3C//4``, split
    budget truncation) can bite, ``2 * n_active > C`` already escalates to the
    full-capacity rung.  Works on ints (host drivers) and traced values
    (device drivers) alike.
    """
    return jnp.minimum(2 * n_active, capacity) if isinstance(
        n_active, jnp.ndarray
    ) else min(2 * int(n_active), capacity)


def donate_argnums(platform: Optional[str] = None) -> tuple[int, ...]:
    """Donate the state buffers of per-iteration dispatches.

    The ``(C, d)`` SoA arrays are the dominant allocation; donating them lets
    XLA update the population in place instead of copying it every step.
    Skipped on CPU, where donation is unimplemented and only triggers a
    warning per compiled executable.  ``platform`` is the platform of the
    devices that will actually run the computation; default backend otherwise.
    """
    platform = platform or jax.default_backend()
    return () if platform == "cpu" else (0,)


def make_advance_step(
    cfg: QuadratureConfig,
    total_volume: float,
    domain_width: np.ndarray,
    window: Optional[int] = None,
) -> Callable[..., RegionState]:
    """Classify (finalise negligible) + split survivors + compact.

    ``budget`` / ``rel_tol`` override the config-derived error budget and
    relative tolerance (the batch service passes per-request tolerances as
    traced values); ``None`` derives them from ``cfg`` as the serial
    drivers do.

    ``window`` runs the whole advance — the global-estimate reduction, the
    classify thresholding, and the sort-based split/compact — on the leading
    ``window`` rows only.  Exact (bit-identical to the full advance) whenever
    ``window >= advance_target(n_active, capacity)``; the drivers guarantee
    this by picking the rung from :func:`advance_ladder` for the active count
    they already track.
    """
    width = jnp.asarray(domain_width)
    w = None if window is None else min(int(window), cfg.capacity)

    def advance(state: RegionState, budget=None, rel_tol=None) -> RegionState:
        sl = slice(None) if w is None else slice(0, w)
        integral, _ = state.global_estimates(window=w)
        fin = classify(
            cfg,
            state.est[sl],
            state.err[sl],
            state.halfw[sl],
            state.active[sl],
            integral,
            total_volume,
            width,
            budget=budget,
            rel_tol=rel_tol,
        )
        state = classify_split_compact(state, fin, window=w)
        return dataclasses.replace(state, it=state.it + 1)

    return advance


def make_switched_advance_step(
    cfg: QuadratureConfig, total_volume: float, domain_width: np.ndarray
) -> Callable[..., RegionState]:
    """Device-resident windowed advance: ``lax.switch`` over the ladder.

    The rung is chosen on device from the live count to cover
    ``advance_target(n_active)`` — the mirror of the host drivers' cached
    per-rung jits, for loops that never sync the count
    (:func:`integrate_device`, the batch engine's fused run).
    """
    ladder = advance_ladder(cfg)
    if len(ladder) == 1:
        return make_advance_step(cfg, total_volume, domain_width)
    branches = [
        make_advance_step(cfg, total_volume, domain_width, window=w)
        for w in ladder
    ]
    rungs = jnp.asarray(ladder, jnp.int32)

    def advance(state: RegionState, budget=None, rel_tol=None) -> RegionState:
        n = jnp.sum(state.active).astype(jnp.int32)
        ix = region_store.rung_index(rungs, advance_target(n, cfg.capacity))
        return jax.lax.switch(ix, branches, state, budget, rel_tol)

    return advance


def make_switched_estimates(cfg: QuadratureConfig) -> Callable[[RegionState], tuple]:
    """Windowed ``global_estimates`` for device-resident loops.

    Any rung covering ``n_active`` is exact (the masked tail contributes
    exact zeros), so the estimate reductions use the plain count — not the
    doubled advance target.  Falls back to the full reduction when advance
    windowing is off.
    """
    ladder = advance_ladder(cfg)
    if len(ladder) == 1:
        return lambda state: state.global_estimates()
    branches = [
        (lambda state, _w=w: state.global_estimates(window=_w)) for w in ladder
    ]
    rungs = jnp.asarray(ladder, jnp.int32)

    def estimates(state: RegionState):
        n = jnp.sum(state.active).astype(jnp.int32)
        return jax.lax.switch(region_store.rung_index(rungs, n), branches, state)

    return estimates


def quarantine_step(state: RegionState):
    """Zero + deactivate non-finite regions, recompute global estimates.

    The cold recovery path for the host drivers: jitted on first use, runs
    at most once per problem (the problem is terminal with status
    ``nonfinite`` immediately after).  The compaction invariant may be
    broken by the mid-store deactivations, which is safe exactly because
    nothing windowed runs afterwards — the full-store reduction here is the
    problem's last device op.
    """
    bad = nonfinite_mask(state.est, state.err, state.active)
    state = dataclasses.replace(
        state,
        est=jnp.where(bad, 0.0, state.est),
        err=jnp.where(bad, 0.0, state.err),
        active=state.active & ~bad,
    )
    integral, error = state.global_estimates()
    return state, integral, error, jnp.sum(state.active)


def _setup(cfg: QuadratureConfig, integrand):
    cfg = cfg.validate()
    lo = np.asarray(cfg.lo(), np.float64)
    hi = np.asarray(cfg.hi(), np.float64)
    total_volume = float(np.prod(hi - lo))
    dtype = jnp.dtype(cfg.dtype)
    rule = make_rule(cfg, integrand)
    state = region_store.init_state(
        cfg.capacity, lo, hi, cfg.resolved_n_init(), dtype
    )
    return cfg, lo, hi, total_volume, rule, state


def result_status(
    converged: bool,
    n_active: int,
    it: int,
    cfg,
    overflowed: bool,
    nonfinite: bool = False,
) -> str:
    """Terminal-status taxonomy shared by the serial drivers and the batch
    service (which promises 'statuses as in AdaptiveResult').

    ``nonfinite`` wins over everything: a quarantined problem's remaining
    finite regions may happen to satisfy the budget, but the quarantined
    volume is unaccounted for, so reporting ``converged`` would overstate
    what the estimate covers.
    """
    if nonfinite:
        return "nonfinite"
    if converged:
        return "converged"
    if overflowed:
        return "capacity"
    if n_active == 0:
        return "no_active"
    if it >= cfg.max_iters:
        return "max_iters"
    return "running"


def integrate(
    cfg: QuadratureConfig,
    integrand: Optional[Callable] = None,
    callback: Optional[Callable[[int, float, float, int], None]] = None,
    recorder=NULL,
) -> AdaptiveResult:
    """Host-driven adaptive integration (one scalar sync per iteration).

    ``recorder`` (a :class:`repro.telemetry.Recorder`) gets per-iteration
    ``core.eval``/``core.advance`` spans and a ``core.iter`` instant with
    the synced estimates — all recorded host-side between dispatches, so
    telemetry cannot change the refinement trajectory.
    """
    cfg, lo, hi, total_volume, rule, state = _setup(cfg, integrand)

    donate = donate_argnums()
    ladder = eval_ladder(cfg)
    adv_ladder = advance_ladder(cfg)
    C = cfg.capacity
    # One jitted variant per ladder rung, compiled on first use.  The host
    # loop already syncs the active count each iteration, so the next window
    # is known before dispatch and the switch costs nothing on device.  The
    # advance (and the metric reductions) get the same treatment as the eval:
    # a per-rung jit cache keyed by the windows the counts demand.
    eval_cache: dict[int, Callable] = {}
    metrics_cache: dict[int, Callable] = {}
    adv_cache: dict[int, Callable] = {}

    def eval_step_for(n_active: int) -> Callable[[RegionState], RegionState]:
        w = region_store.select_window(ladder, n_active)
        fn = eval_cache.get(w)
        if fn is None:
            fn = jax.jit(make_eval_step(cfg, rule, window=w), donate_argnums=donate)
            eval_cache[w] = fn
        return fn

    def metrics_for(n_active: int) -> Callable:
        # any rung covering n_active reduces the same active mass bit-exactly
        w = region_store.select_window(adv_ladder, n_active)
        fn = metrics_cache.get(w)
        if fn is None:
            ww = None if w == C else w

            def metrics(state, _w=ww):
                integral, error = state.global_estimates(window=_w)
                act = state.active if _w is None else state.active[:_w]
                return integral, error, jnp.sum(act)

            fn = jax.jit(metrics)
            metrics_cache[w] = fn
        return fn

    def advance_for(n_active: int) -> Callable:
        w = region_store.select_window(adv_ladder, advance_target(n_active, C))
        fn = adv_cache.get(w)
        if fn is None:
            ww = None if w == C else w
            core = make_advance_step(cfg, total_volume, hi - lo, window=ww)

            def advance_and_count(state, _core=core, _w=ww):
                state = _core(state)
                # post-split the population fits the advance window
                act = state.active if _w is None else state.active[:_w]
                return state, jnp.sum(act)

            fn = jax.jit(advance_and_count, donate_argnums=donate)
            adv_cache[w] = fn
        return fn

    converged = False
    nonfinite = False
    integral = error = 0.0
    n_active = n_next = cfg.resolved_n_init()
    for _ in range(cfg.max_iters):
        with recorder.span("core.eval", window=int(n_next)):
            state = eval_step_for(n_next)(state)
            integral, error, n_active = (
                float(x) for x in metrics_for(n_next)(state)
            )
        if recorder.enabled:
            recorder.event(
                "core.iter",
                it=int(state.it),
                integral=integral,
                error=error,
                n_active=int(n_active),
            )
        if callback is not None:
            callback(int(state.it), integral, error, int(n_active))
        if not (np.isfinite(integral) and np.isfinite(error)):
            # an integrand NaN/Inf reached the global reductions: quarantine
            # the offending regions and stop with the best-effort estimate
            # of the surviving population (terminal status "nonfinite")
            state, gi, ge, na = jax.jit(quarantine_step)(state)
            integral, error, n_active = float(gi), float(ge), int(na)
            nonfinite = True
            break
        budget = max(cfg.abs_tol, abs(integral) * cfg.rel_tol)
        if error <= budget:
            converged = True
            break
        if n_active == 0:
            break
        with recorder.span("core.advance", n_active=int(n_active)):
            state, n_dev = advance_for(int(n_active))(state)
            n_next = int(n_dev)

    return AdaptiveResult(
        integral=integral,
        error=error,
        status=result_status(
            converged,
            int(n_active),
            int(state.it),
            cfg,
            bool(state.overflowed),
            nonfinite,
        ),
        iterations=int(state.it),
        n_evals=float(state.n_evals),
        n_active=int(n_active),
        overflowed=bool(state.overflowed),
    )


def integrate_device(
    cfg: QuadratureConfig, integrand: Optional[Callable] = None, recorder=NULL
) -> AdaptiveResult:
    """Fully device-resident driver: lax.while_loop, zero host syncs.

    The host cannot observe per-iteration state here, so telemetry is one
    ``core.device_loop`` span around the whole resident loop — by design
    (DESIGN.md §8: nothing is ever recorded inside traced code).
    """
    cfg, lo, hi, total_volume, rule, state = _setup(cfg, integrand)
    eval_step = make_switched_eval_step(cfg, rule)
    advance = make_switched_advance_step(cfg, total_volume, hi - lo)
    estimates = make_switched_estimates(cfg)

    def cond(state: RegionState):
        integral, error = estimates(state)
        pending = jnp.any(state.active & state.fresh)
        converged = (error <= error_budget(cfg, integral)) & ~pending
        return (~converged) & (state.it < cfg.max_iters) & jnp.any(state.active)

    def body(state: RegionState):
        state = eval_step(state)
        integral, error = estimates(state)
        done = error <= error_budget(cfg, integral)
        # Only refine when not converged (cond re-checks next trip).
        return jax.lax.cond(done, lambda s: s, advance, state)

    with recorder.span("core.device_loop", max_iters=cfg.max_iters):
        final = jax.lax.while_loop(cond, body, state)
        integral, error = (float(x) for x in final.global_estimates())
        n_active = int(final.n_active())
    # the device-resident loop has no recovery path (NaN fails the on-device
    # convergence check until another bound fires); report honestly
    nonfinite = not (np.isfinite(integral) and np.isfinite(error))
    budget = max(cfg.abs_tol, abs(integral) * cfg.rel_tol)
    converged = error <= budget
    return AdaptiveResult(
        integral=integral,
        error=error,
        status=result_status(
            converged,
            n_active,
            int(final.it),
            cfg,
            bool(final.overflowed),
            nonfinite,
        ),
        iterations=int(final.it),
        n_evals=float(final.n_evals),
        n_active=n_active,
        overflowed=bool(final.overflowed),
    )


def integrate_exact_check(cfg: QuadratureConfig) -> tuple[AdaptiveResult, float]:
    """Convenience: integrate a registry integrand and return true rel-error."""
    spec = get_integrand(cfg.integrand)
    res = integrate(cfg)
    exact = spec.exact(cfg.d)
    rel = abs(res.integral - exact) / max(abs(exact), 1e-300)
    return res, rel
