"""Multi-device adaptive quadrature (paper Fig. 1b) via shard_map.

Each device owns a fixed-capacity region store and runs the single-device
iteration locally; three collectives per iteration implement the paper's
distributed extension:

  1. *metadata exchange* — `psum` of (integral, error, active count) right
     after evaluation: the paper's compact per-iteration summary and its only
     global synchronisation point.  Convergence is decided on these values —
     on device, so ``sync_every`` iterations can be fused into one dispatch
     and the host only reads back (stacked) metrics at that cadence.
  2. *classification with global context* — the equal-share classifier uses
     the GLOBAL active count, so all devices finalise against the same
     threshold (a single-device run and a P-device run of the same problem
     therefore walk the same refinement tree, modulo redistribution).
  3. *redistribution* — `repro.core.redistribution.redistribute`: cyclic
     donor/receiver pairing, capped coordinate-only payloads, overlapping
     with compute courtesy of XLA's latency-hiding scheduler.

The initial domain decomposition over-partitions: ``init_regions_per_device``
(paper default 8) boxes per rank, assigned round-robin so neighbouring boxes
land on different ranks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import region_store
from repro.core.adaptive import (
    AdaptiveResult,
    advance_ladder,
    advance_target,
    donate_argnums,
    make_switched_estimates,
    make_switched_eval_step,
)
from repro.core.classify import classify, error_budget
from repro.core.config import QuadratureConfig
from repro.core.redistribution import balance_stats, make_schedule, redistribute
from repro.core.region_store import RegionState
from repro.core.rules import make_rule
from repro.core.split import classify_split_compact

AXIS = "dev"


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map with the replication checker disabled.

    Loop carries built inside the body start device-invariant and become
    device-varying after the first iteration; the static vma/rep checker
    cannot express that, so it is disabled.  jax >= 0.5 exposes
    ``jax.shard_map(check_vma=...)``; older releases only have
    ``jax.experimental.shard_map.shard_map(check_rep=...)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as sm

    return sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


@dataclasses.dataclass
class DistributedResult(AdaptiveResult):
    n_devices: int = 1
    # per-iteration history rows:
    #   (iter, integral, error, n_active, work_imbalance, max_rows)
    history: list = dataclasses.field(default_factory=list)
    # final per-device evaluation counts (work distribution; Fig. 4b input)
    evals_per_device: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0)
    )

    def mean_imbalance(self) -> float:
        if not self.history:
            return 0.0
        return float(np.mean([h[4] for h in self.history]))


def _initial_global_partition(cfg: QuadratureConfig, n_devices: int):
    """Over-decomposed initial partition, strided across ranks."""
    lo = np.asarray(cfg.lo(), np.float64)
    hi = np.asarray(cfg.hi(), np.float64)
    want = n_devices * cfg.init_regions_per_device
    # keep the "every axis split at least once" guarantee of the
    # single-device driver (see QuadratureConfig.n_init)
    want = max(want, min(2**cfg.d, n_devices * cfg.capacity // 4))
    n_init = 1 << (want - 1).bit_length()  # next power of two
    n_init = min(n_init, n_devices * (cfg.capacity // 4))
    centers, halfw = region_store.uniform_partition(lo, hi, n_init)
    return centers, halfw, n_init


def _stacked_initial_state(cfg: QuadratureConfig, n_devices: int, dtype):
    centers, halfw, n_init = _initial_global_partition(cfg, n_devices)
    C, d = cfg.capacity, cfg.d
    per_dev = -(-n_init // n_devices)
    if per_dev > C // 2:
        raise ValueError("initial partition exceeds half the per-device store")

    stacked = {
        "centers": np.zeros((n_devices, C, d)),
        "halfw": np.zeros((n_devices, C, d)),
        "active": np.zeros((n_devices, C), bool),
        "fresh": np.zeros((n_devices, C), bool),
    }
    counts = np.zeros(n_devices, np.int64)
    for r in range(n_init):
        dev = r % n_devices  # strided assignment (paper: several regions/rank)
        slot = counts[dev]
        stacked["centers"][dev, slot] = centers[r]
        stacked["halfw"][dev, slot] = halfw[r]
        stacked["active"][dev, slot] = True
        stacked["fresh"][dev, slot] = True
        counts[dev] += 1

    z = jnp.zeros
    return RegionState(
        centers=jnp.asarray(stacked["centers"], dtype),
        halfw=jnp.asarray(stacked["halfw"], dtype),
        est=z((n_devices, C), dtype),
        err=z((n_devices, C), dtype),
        axis=z((n_devices, C), jnp.int32),
        active=jnp.asarray(stacked["active"]),
        fresh=jnp.asarray(stacked["fresh"]),
        fin_integral=z((n_devices,), dtype),
        fin_error=z((n_devices,), dtype),
        n_evals=z((n_devices,), dtype),
        it=z((n_devices,), jnp.int32),
        overflowed=z((n_devices,), bool),
    )


def make_switched_classify_split(
    cfg: QuadratureConfig, total_volume: float, domain_width: np.ndarray
):
    """Windowed classify + split/compact for the per-device fused step.

    Unlike :func:`repro.core.adaptive.make_advance_step` this takes the
    *psum'd* integral and global active count (every device classifies
    against the same equal-share threshold) and does NOT bump ``it`` — the
    redistribution schedule indexes on the pre-bump counter.  The window rung
    is picked per device from its LOCAL live count (the branches contain no
    collectives, so devices may take different branches under SPMD).
    """
    width = jnp.asarray(domain_width)
    ladder = advance_ladder(cfg)
    C = cfg.capacity

    def branch(w: Optional[int]):
        sl = slice(None) if w is None else slice(0, w)

        def fn(state: RegionState, integral, n_global) -> RegionState:
            fin = classify(
                cfg,
                state.est[sl],
                state.err[sl],
                state.halfw[sl],
                state.active[sl],
                integral,
                total_volume,
                width,
                n_active=n_global,
            )
            return classify_split_compact(state, fin, window=w)

        return fn

    if len(ladder) == 1:
        return branch(None)
    branches = [branch(w) for w in ladder]
    rungs = jnp.asarray(ladder, jnp.int32)

    def apply(state: RegionState, integral, n_global) -> RegionState:
        n = jnp.sum(state.active).astype(jnp.int32)
        ix = region_store.rung_index(rungs, advance_target(n, C))
        return jax.lax.switch(ix, branches, state, integral, n_global)

    return apply


def make_dist_step(
    cfg: QuadratureConfig,
    rule,
    n_devices: int,
    total_volume: float,
    domain_width: np.ndarray,
    schedule,
):
    """K-fused per-device step (K = ``cfg.sync_every``).

    ``dist_step`` runs up to K full iterations inside one dispatch.  The
    convergence check runs on device against the psum'd metadata (which is
    identical on every rank, so all ranks take the same branch) and iterations
    after convergence become pass-throughs; the host only syncs once per
    dispatch, reading back the stacked per-iteration metrics plus an
    ``executed`` mask — the paper's "overlap communication with computation"
    applied to the host<->device channel.
    """
    eval_step = make_switched_eval_step(cfg, rule)
    estimates = make_switched_estimates(cfg)
    classify_split = make_switched_classify_split(cfg, total_volume, domain_width)
    limit = 3 * cfg.capacity // 4
    dtype = jnp.dtype(cfg.dtype)

    def dist_core(state: RegionState):
        work_loc = jnp.sum(state.active & state.fresh)
        state = eval_step(state)

        # --- metadata exchange (the only global sync point) ----------------
        i_loc, e_loc = estimates(state)
        integral = jax.lax.psum(i_loc, AXIS)
        error = jax.lax.psum(e_loc, AXIS)
        n_loc = jnp.sum(state.active)
        n_global = jax.lax.psum(n_loc, AXIS)
        work_max = jax.lax.pmax(work_loc, AXIS)
        work_sum = jax.lax.psum(work_loc, AXIS)
        work_imb = jnp.where(
            work_max > 0,
            1.0 - (work_sum / n_devices) / jnp.maximum(work_max, 1),
            0.0,
        )
        max_rows, _, _ = balance_stats(n_loc, AXIS, n_devices)

        # --- classify + split (global equal-share threshold) ---------------
        state = classify_split(state, integral, n_global)

        # --- decentralised redistribution ----------------------------------
        if cfg.redistribution != "off":
            state = redistribute(
                state,
                axis_name=AXIS,
                n_devices=n_devices,
                schedule=schedule,
                cap=cfg.message_cap,
                limit=limit,
            )
        state = dataclasses.replace(state, it=state.it + 1)

        metrics = {
            "integral": integral.astype(dtype),
            "error": error.astype(dtype),
            "n_active": n_global.astype(jnp.int32),
            "work_imb": work_imb.astype(dtype),
            "max_rows": max_rows.astype(jnp.int32),
        }
        return state, metrics

    def _zero_metrics():
        return {
            "integral": jnp.zeros((), dtype),
            "error": jnp.zeros((), dtype),
            "n_active": jnp.zeros((), jnp.int32),
            "work_imb": jnp.zeros((), dtype),
            "max_rows": jnp.zeros((), jnp.int32),
        }

    def dist_step(state: RegionState):
        # squeeze the leading per-device axis added by shard_map
        state = jax.tree.map(lambda x: x[0], state)

        def one(carry, _):
            state, done = carry
            executed = ~done

            def run(s):
                s2, m = dist_core(s)
                # device-side convergence: the same decision the host made
                # per-iteration, on the same psum'd (replicated) metadata
                stop = (
                    (m["error"] <= error_budget(cfg, m["integral"]))
                    | (m["n_active"] == 0)
                    | (s2.it >= cfg.max_iters)
                )
                return s2, stop, m

            def skip(s):
                return s, jnp.asarray(True), _zero_metrics()

            state, done, m = jax.lax.cond(done, skip, run, state)
            return (state, done), (m, executed)

        (state, _), (ms, executed) = jax.lax.scan(
            one, (state, jnp.asarray(False)), None, length=cfg.sync_every
        )
        state = jax.tree.map(lambda x: x[None], state)
        return state, ms, executed

    return dist_step


def integrate_distributed(
    cfg: QuadratureConfig,
    integrand: Optional[Callable] = None,
    mesh: Optional[Mesh] = None,
    devices=None,
    recorder=None,
) -> DistributedResult:
    """Host-driven multi-device integration over all available devices.

    ``recorder`` (a :class:`repro.telemetry.Recorder`) gets a
    ``dist.dispatch`` span per fused launch and, per executed iteration, a
    ``dist.work_imb`` gauge (the paper's Fig. 4b idle-time proxy, the same
    value appended to ``history``) plus a ``dist.iter`` instant — recorded
    from the read-back metrics only, after the dispatch returns.
    """
    cfg = cfg.validate()
    if mesh is None:
        devices = devices if devices is not None else jax.devices()
        mesh = jax.make_mesh((len(devices),), (AXIS,), devices=devices)
    n_devices = mesh.shape[AXIS]

    lo = np.asarray(cfg.lo(), np.float64)
    hi = np.asarray(cfg.hi(), np.float64)
    total_volume = float(np.prod(hi - lo))
    dtype = jnp.dtype(cfg.dtype)
    rule = make_rule(cfg, integrand)
    schedule = make_schedule(n_devices)

    state = _stacked_initial_state(cfg, n_devices, dtype)
    shard = NamedSharding(mesh, P(AXIS))
    state = jax.device_put(state, shard)

    dist_step = make_dist_step(
        cfg, rule, n_devices, total_volume, hi - lo, schedule
    )
    step = jax.jit(
        _shard_map(
            dist_step,
            mesh=mesh,
            in_specs=P(AXIS),
            out_specs=(P(AXIS), P(), P()),
        ),
        donate_argnums=donate_argnums(mesh.devices.flat[0].platform),
    )

    from repro.telemetry import NULL

    recorder = NULL if recorder is None else recorder
    history = []
    converged = False
    integral = error = 0.0
    n_active = 0
    it = 0
    while it < cfg.max_iters:
        with recorder.span("dist.dispatch", it=it) as sp:
            state, ms, executed = step(state)
            executed = np.asarray(executed)
            ms = jax.device_get(ms)
            sp["executed"] = int(np.sum(executed))
        for t in range(len(executed)):
            if not executed[t]:
                break
            integral = float(ms["integral"][t])
            error = float(ms["error"][t])
            n_active = int(ms["n_active"][t])
            work_imb = float(ms["work_imb"][t])
            if recorder.enabled:
                recorder.gauge("dist.work_imb", work_imb, it=it)
                recorder.event(
                    "dist.iter",
                    it=it,
                    integral=integral,
                    error=error,
                    n_active=n_active,
                    max_rows=int(ms["max_rows"][t]),
                )
            history.append(
                (
                    it,
                    integral,
                    error,
                    n_active,
                    work_imb,
                    int(ms["max_rows"][t]),
                )
            )
            it += 1
        budget = max(cfg.abs_tol, abs(integral) * cfg.rel_tol)
        if error <= budget:
            converged = True
            break
        if n_active == 0:
            break

    overflowed = bool(np.any(np.asarray(state.overflowed)))
    if converged:
        status = "converged"
    elif overflowed:
        status = "capacity"
    elif n_active == 0:
        status = "no_active"
    else:
        status = "max_iters"

    return DistributedResult(
        integral=integral,
        error=error,
        status=status,
        iterations=it,
        n_evals=float(np.sum(np.asarray(state.n_evals))),
        n_active=n_active,
        overflowed=overflowed,
        n_devices=n_devices,
        history=history,
        evals_per_device=np.asarray(state.n_evals),
    )
