"""Unified architecture configuration covering the 10 assigned families."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention
    causal: bool = True
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0
    moe_layer_period: int = 1  # MoE on layers where i % period == period-1
    first_k_dense: int = 0  # first K layers always use the dense MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid interleave (Jamba): attention on layers where
    # i % attn_layer_period == attn_layer_offset; 0 period => per-family default
    attn_layer_period: int = 0
    attn_layer_offset: int = 0
    # modality frontend stub ([vlm]/[audio] — precomputed embeddings input)
    frontend: str = "none"  # none | vision | audio
    n_frontend_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"  # activations
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' mixer for layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            period = self.attn_layer_period or 8
            return "attn" if i % period == self.attn_layer_offset else "ssm"
        return "attn"

    def layer_has_moe(self, i: int) -> bool:
        if not self.moe_experts:
            return False
        if i < self.first_k_dense:
            return False
        return i % self.moe_layer_period == self.moe_layer_period - 1

    @property
    def block_pattern_period(self) -> int:
        """Length of the periodic layer pattern (scan unit = one period)."""
        p = 1
        if self.family == "hybrid":
            p = self.attn_layer_period or 8
        if self.moe_experts:
            p = _lcm(p, self.moe_layer_period)
        return p

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only models have no decode step

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is in-family (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d = self.d_model
        total = self.vocab_size * d * 2  # embed + untied head
        for i in range(self.n_layers):
            total += 2 * d  # two norms
            if self.layer_kind(i) == "attn":
                if self.use_mla:
                    qd = self.qk_nope_head_dim + self.qk_rope_head_dim
                    q_in = self.q_lora_rank or d
                    if self.q_lora_rank:
                        total += d * self.q_lora_rank
                    total += q_in * self.n_heads * qd
                    total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    total += self.n_heads * self.v_head_dim * d
                else:
                    hd = self.resolved_head_dim
                    total += d * self.n_heads * hd  # q
                    total += 2 * d * self.n_kv_heads * hd  # k, v
                    total += self.n_heads * hd * d  # o
            else:
                di, ns, hs = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + hs)  # in_proj (x,z,B,C,dt)
                total += (di + 2 * ns) * self.ssm_conv  # conv
                total += 3 * hs + di  # A_log, D, dt_bias, gated-norm scale
                total += di * d  # out_proj
            if self.layer_has_moe(i):
                e, fd = self.moe_experts, self.moe_d_ff or self.d_ff
                total += d * e  # router
                total += e * 3 * d * fd
                total += self.moe_shared_experts * 3 * d * fd
            elif self.d_ff:
                total += 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        fd = self.moe_d_ff or self.d_ff
        inactive_experts = self.moe_experts - self.moe_top_k
        n_moe_layers = sum(
            self.layer_has_moe(i) for i in range(self.n_layers)
        )
        return self.param_count() - n_moe_layers * inactive_experts * 3 * d * fd


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)
