"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a per-token latent ``c_kv`` of rank ``kv_lora_rank``
plus a single shared RoPE key of ``qk_rope_head_dim`` — the serving cache
stores only ``kv_lora_rank + qk_rope_head_dim`` floats per token regardless
of head count (the memory win that defines the architecture).  Queries carry
a no-RoPE part (matched against up-projected latent keys) and a RoPE part
(matched against the shared rotary key).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    rmsnorm,
    rmsnorm_init,
    truncated_normal_init,
)


def mla_init(cfg: ModelConfig, key):
    d = cfg.d_model
    nh = cfg.n_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    keys = jax.random.split(key, 8)
    q_in = cfg.q_lora_rank or d
    params = {
        # queries (optionally low-rank)
        "wq_b": truncated_normal_init(keys[1], (q_in, nh * qd), 1.0),
        # latent KV compression + shared rotary key
        "w_kv_a": truncated_normal_init(
            keys[2], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim), 1.0
        ),
        "kv_a_norm": rmsnorm_init(cfg.kv_lora_rank),
        # latent -> per-head K_nope and V
        "w_k_b": truncated_normal_init(
            keys[3], (cfg.kv_lora_rank, nh * cfg.qk_nope_head_dim), 1.0
        ),
        "w_v_b": truncated_normal_init(
            keys[4], (cfg.kv_lora_rank, nh * cfg.v_head_dim), 1.0
        ),
        "wo": truncated_normal_init(keys[5], (nh * cfg.v_head_dim, d), 1.0),
    }
    if cfg.q_lora_rank:
        params["wq_a"] = truncated_normal_init(keys[0], (d, cfg.q_lora_rank), 1.0)
        params["q_a_norm"] = rmsnorm_init(cfg.q_lora_rank)
    return params


def _queries(cfg: ModelConfig, params, x, positions):
    b, s, _ = x.shape
    dtype = x.dtype
    nh = cfg.n_heads
    if cfg.q_lora_rank:
        cq = rmsnorm(params["q_a_norm"], x @ params["wq_a"].astype(dtype), cfg.norm_eps)
    else:
        cq = x
    q = (cq @ params["wq_b"].astype(dtype)).reshape(
        b, s, nh, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(cfg: ModelConfig, params, x, positions):
    dtype = x.dtype
    kv = x @ params["w_kv_a"].astype(dtype)  # (B, S, rank + rope)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_a_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _attend(cfg: ModelConfig, params, q_nope, q_rope, c_kv, k_rope, kv_valid_len):
    """Attention against the latent cache, streamed over KV blocks.

    Scores are computed in the latent space: q_nope is absorbed into the
    latent up-projection (q_nope @ w_k_b^T per head), so the cache is read
    once per block with no per-head K materialisation — the TPU-friendly
    "weight absorption" form of MLA decoding.
    """
    b, sq, nh, _ = q_nope.shape
    dtype = q_nope.dtype
    rank = cfg.kv_lora_rank
    w_k_b = params["w_k_b"].astype(jnp.float32).reshape(rank, nh, cfg.qk_nope_head_dim)
    w_v_b = params["w_v_b"].astype(jnp.float32).reshape(rank, nh, cfg.v_head_dim)

    # absorb: q_lat (B, Sq, H, rank)
    q_lat = jnp.einsum(
        "bqhd,rhd->bqhr", q_nope.astype(jnp.float32), w_k_b
    )
    scale = float(1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))

    sk = c_kv.shape[1]
    block = min(2048, sk)
    if sk % block:
        pad = block - sk % block
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
        sk += pad
    n_blocks = sk // block
    q_pos = (kv_valid_len - sq) + jnp.arange(sq)  # absolute query positions

    def body(carry, blk):
        m, l, acc = carry
        cb = jax.lax.dynamic_slice_in_dim(c_kv, blk * block, block, 1).astype(
            jnp.float32
        )
        rb = jax.lax.dynamic_slice_in_dim(k_rope, blk * block, block, 1).astype(
            jnp.float32
        )
        s = jnp.einsum("bqhr,bkr->bqhk", q_lat, cb)
        s += jnp.einsum("bqhd,bkd->bqhk", q_rope.astype(jnp.float32), rb)
        s *= scale
        kv_pos = blk * block + jnp.arange(block)
        mask = (q_pos[:, None] >= kv_pos[None, :]) & (
            kv_pos < kv_valid_len
        )[None, :]
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask[None, :, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        # accumulate in latent space; project to V after the scan
        acc_new = acc * alpha[..., None] + jnp.einsum("bqhk,bkr->bqhr", p, cb)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, sq, nh), -jnp.inf, jnp.float32),
        jnp.zeros((b, sq, nh), jnp.float32),
        jnp.zeros((b, sq, nh, rank), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    lat_out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, Sq, H, rank)
    out = jnp.einsum("bqhr,rhd->bqhd", lat_out, w_v_b)  # (B, Sq, H, v_dim)
    return out.reshape(b, sq, nh * cfg.v_head_dim).astype(dtype)


def mla_forward(cfg: ModelConfig, params, x, positions):
    b, s, _ = x.shape
    q_nope, q_rope = _queries(cfg, params, x, positions)
    c_kv, k_rope = _latent_kv(cfg, params, x, positions)
    out = _attend(cfg, params, q_nope, q_rope, c_kv, k_rope, kv_valid_len=s)
    return out @ params["wo"].astype(x.dtype)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(cfg: ModelConfig, params, x, positions, cache):
    b, s, _ = x.shape
    q_nope, q_rope = _queries(cfg, params, x, positions)
    c_kv, k_rope = _latent_kv(cfg, params, x, positions)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, 0, 1),
    }
    out = _attend(cfg, params, q_nope, q_rope, c_kv, k_rope, kv_valid_len=s)
    return out @ params["wo"].astype(x.dtype), cache


def mla_extend(cfg: ModelConfig, params, x, cache, pos):
    """Extend the latent cache by S tokens at position ``pos`` (S=1: decode;
    S=chunk: chunked prefill) and attend causally against the cache."""
    b, s, _ = x.shape
    positions = pos + jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    q_nope, q_rope = _queries(cfg, params, x, positions)
    c_kv, k_rope = _latent_kv(cfg, params, x, positions)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, pos, 0)),
    }
    out = _attend(
        cfg, params, q_nope, q_rope, cache["c_kv"], cache["k_rope"],
        kv_valid_len=pos + s,
    )
    return out @ params["wo"].astype(x.dtype), cache


def mla_decode(cfg: ModelConfig, params, x, cache, pos):
    return mla_extend(cfg, params, x, cache, pos)
