"""GQA attention (with optional per-head QK-norm) + KV-cache serving path."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_rope,
    blockwise_attention,
    rmsnorm,
    rmsnorm_init,
    truncated_normal_init,
)


def attn_init(cfg: ModelConfig, key):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": truncated_normal_init(k1, (cfg.d_model, cfg.n_heads * hd), 1.0),
        "wk": truncated_normal_init(k2, (cfg.d_model, cfg.n_kv_heads * hd), 1.0),
        "wv": truncated_normal_init(k3, (cfg.d_model, cfg.n_kv_heads * hd), 1.0),
        "wo": truncated_normal_init(k4, (cfg.n_heads * hd, cfg.d_model), 1.0),
    }
    if cfg.qk_norm:
        params["q_norm"] = rmsnorm_init(hd)
        params["k_norm"] = rmsnorm_init(hd)
    return params


def _project_qkv(cfg: ModelConfig, params, x, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    dtype = x.dtype
    q = (x @ params["wq"].astype(dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (x @ params["wk"].astype(dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ params["wv"].astype(dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(cfg: ModelConfig, params, x, positions, kv_block: int = 1024):
    """Training / encoding path (no cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, positions)
    out = blockwise_attention(q, k, v, causal=cfg.causal, kv_block=kv_block)
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype)


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_prefill(cfg: ModelConfig, params, x, positions, cache, kv_block=1024):
    """Full-sequence forward that also fills cache[:, :S]."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, positions)
    out = blockwise_attention(q, k, v, causal=cfg.causal, kv_block=kv_block)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1),
    }
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype), cache


def attn_extend(cfg: ModelConfig, params, x, cache, pos, kv_block: int = 2048):
    """Extend the cache by S tokens starting at absolute position ``pos`` and
    attend causally against everything cached so far.  S=1 is classic decode;
    S=chunk is chunked prefill (Sarathi-style), which bounds the per-step MoE
    dispatch/attention working set for very long prompts."""
    b, s, _ = x.shape
    positions = pos + jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    q, k, v = _project_qkv(cfg, params, x, positions)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0)),
    }
    out = blockwise_attention(
        q,
        cache["k"],
        cache["v"],
        causal=True,
        q_offset=pos,
        kv_valid_len=pos + s,
        kv_block=kv_block,
    )
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype), cache


def attn_decode(cfg: ModelConfig, params, x, cache, pos, kv_block: int = 2048):
    """One-token step: x (B, 1, d); pos () current absolute position."""
    return attn_extend(cfg, params, x, cache, pos, kv_block=kv_block)
