"""Mixture-of-Experts FFN with sort-based, capacity-bounded dispatch.

Dispatch is the load-balancing problem the paper's redistribution policy
solves for quadrature regions: token load per expert is data-dependent and
skewed, so the dispatcher bounds per-expert work with a static capacity
(donor/receiver rebalancing happens implicitly through the router's aux
loss; overflow tokens fall back to the residual stream).  The sort-based
formulation keeps every shape static for XLA:

  1. route: top-k expert ids + renormalised probs per token,
  2. stable-sort the (T*k) assignments by expert id,
  3. position-within-expert via the sorted prefix; drop beyond capacity,
  4. gather tokens into (E, capacity, d) buffers — sharded over the 'model'
     mesh axis, so under GSPMD this step lowers to the expert-parallel
     all-to-all — run the expert SwiGLU as batched einsums, scatter back.

Shared experts (DeepSeek-V2) are dense SwiGLUs applied to every token.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal_init


def moe_init(cfg: ModelConfig, key):
    d = cfg.d_model
    fd = cfg.moe_d_ff or cfg.d_ff
    e = cfg.moe_experts
    keys = jax.random.split(key, 5)
    params = {
        "router": truncated_normal_init(keys[0], (d, e), 1.0),
        "w_gate": truncated_normal_init(keys[1], (e, d, fd), 1.0),
        "w_up": truncated_normal_init(keys[2], (e, d, fd), 1.0),
        "w_down": truncated_normal_init(keys[3], (e, fd, d), 1.0),
    }
    if cfg.moe_shared_experts:
        se = cfg.moe_shared_experts
        ks = jax.random.split(keys[4], 3)
        params["shared"] = {
            "w_gate": truncated_normal_init(ks[0], (d, se * fd), 1.0),
            "w_up": truncated_normal_init(ks[1], (d, se * fd), 1.0),
            "w_down": truncated_normal_init(ks[2], (se * fd, d), 1.0),
        }
    return params


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.moe_top_k / cfg.moe_experts)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_apply(cfg: ModelConfig, params, x):
    """x: (B, S, d) -> (out (B, S, d), aux_metrics dict)."""
    b, s, d = x.shape
    dtype = x.dtype
    t = b * s
    k = cfg.moe_top_k
    e = cfg.moe_experts
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)

    # --- routing -------------------------------------------------------------
    logits = (xt.astype(jnp.float32)) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_p, top_i = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style) + router z-loss
    density = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(1), axis=0
    )  # fraction of tokens per expert (x k)
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(density / k * mean_prob)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # --- sort-based dispatch ---------------------------------------------------
    flat_e = top_i.reshape(-1)  # (T*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=e)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[sorted_e]  # rank within expert
    keep = pos < cap
    token_of = sort_idx // k  # source token of each sorted slot

    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)  # OOB -> dropped
    buf = jnp.zeros((e * cap, d), dtype)
    buf = buf.at[dest].set(xt[token_of], mode="drop")
    buf = buf.reshape(e, cap, d)
    # the dispatch buffers live (experts -> EP axis) x (capacity -> DP axes);
    # the scatter above is therefore the expert-parallel all-to-all
    buf = shard(buf, "experts", "expert_cap", None)

    # --- expert computation (batched einsum over the expert axis) -------------
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    act = jax.nn.silu(gate) * up
    act = shard(act, "experts", "expert_cap", None)
    out_buf = jnp.einsum("ecf,efd->ecd", act, params["w_down"].astype(dtype))
    out_buf = shard(out_buf, "experts", "expert_cap", None)

    # --- combine ---------------------------------------------------------------
    gathered = out_buf.reshape(e * cap, d)[jnp.minimum(dest, e * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weight = top_p.reshape(-1)[sort_idx].astype(dtype)
    out = jnp.zeros((t, d), dtype).at[token_of].add(gathered * weight[:, None])

    if cfg.moe_shared_experts:
        sp = params["shared"]
        g = xt @ sp["w_gate"].astype(dtype)
        u = xt @ sp["w_up"].astype(dtype)
        out = out + (jax.nn.silu(g) * u) @ sp["w_down"].astype(dtype)

    dropped = (jnp.sum(~keep) / (t * k)).astype(jnp.float32)
    metrics = {"aux_loss": aux_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return out.reshape(b, s, d), metrics


def moe_ref_dense(cfg: ModelConfig, params, x):
    """Oracle: run EVERY expert densely and mix by (unclipped) router probs.

    Equal to `moe_apply` whenever no token is dropped (capacity unhit);
    used by the property tests.
    """
    b, s, d = x.shape
    dtype = x.dtype
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    weights = jnp.zeros_like(probs)
    weights = jax.vmap(lambda w, i, p: w.at[i].set(p))(weights, top_i, top_p)

    gate = jnp.einsum("td,edf->etf", xt, params["w_gate"].astype(dtype))
    up = jnp.einsum("td,edf->etf", xt, params["w_up"].astype(dtype))
    act = jax.nn.silu(gate) * up
    per_expert = jnp.einsum("etf,efd->etd", act, params["w_down"].astype(dtype))
    out = jnp.einsum("etd,te->td", per_expert, weights.astype(dtype))
    if cfg.moe_shared_experts:
        sp = params["shared"]
        g = xt @ sp["w_gate"].astype(dtype)
        u = xt @ sp["w_up"].astype(dtype)
        out = out + (jax.nn.silu(g) * u) @ sp["w_down"].astype(dtype)
    return out.reshape(b, s, d)
