"""Model assembly: periodic block pattern, scan-over-layers, serve paths.

Layers are grouped into *periods* (the repeating pattern of mixer/FFN kinds
— length 1 for homogeneous stacks, 8 for Jamba's 1-attention:7-mamba
interleave).  Parameters for one period are initialised per-layer and
stacked across periods, so the forward pass is a single ``lax.scan`` whose
body unrolls one period: the compiled HLO contains ONE period body
regardless of depth (94-layer qwen3-moe compiles as fast as 24-layer
internvl2), and remat policy wraps the same unit.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import mamba2 as ssm_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    embedding_init,
    embedding_lookup,
    head_apply,
    head_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _mixer_init(cfg: ModelConfig, layer_idx: int, key):
    if cfg.layer_kind(layer_idx) == "ssm":
        return ssm_mod.mamba_init(cfg, key)
    if cfg.use_mla:
        return mla_mod.mla_init(cfg, key)
    return attn_mod.attn_init(cfg, key)


def _ffn_init(cfg: ModelConfig, layer_idx: int, key):
    if cfg.layer_has_moe(layer_idx):
        return moe_mod.moe_init(cfg, key)
    if cfg.d_ff == 0:  # pure-mamba blocks: the mixer IS the block
        return {}
    return mlp_init(key, cfg.d_model, cfg.d_ff)


def _block_init(cfg: ModelConfig, layer_idx: int, key):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model),
        "mixer": _mixer_init(cfg, layer_idx, k1),
        "norm2": rmsnorm_init(cfg.d_model),
        "ffn": _ffn_init(cfg, layer_idx, k2),
    }


def model_init(cfg: ModelConfig, key) -> dict:
    period = cfg.block_pattern_period
    n_scan = (cfg.n_layers - cfg.first_k_dense) // period
    assert cfg.first_k_dense + n_scan * period == cfg.n_layers, (
        cfg.n_layers,
        cfg.first_k_dense,
        period,
    )
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "head": head_init(keys[1], cfg.d_model, cfg.vocab_size),
    }
    # prologue layers (e.g. deepseek-v2's first dense layer), unstacked
    params["prologue"] = [
        _block_init(cfg, i, keys[2 + i]) for i in range(cfg.first_k_dense)
    ]
    # scanned stack: one period of blocks, stacked across n_scan repeats
    per_period = []
    for p in range(n_scan):
        blocks = {}
        for j in range(period):
            layer_idx = cfg.first_k_dense + p * period + j
            blocks[f"b{j}"] = _block_init(
                cfg, layer_idx, keys[2 + cfg.first_k_dense + p * period + j]
            )
        per_period.append(blocks)
    params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    return params


# ---------------------------------------------------------------------------
# forward (training / encoding)
# ---------------------------------------------------------------------------


def _block_apply(cfg: ModelConfig, layer_idx: int, params, x, positions, aux):
    kind = cfg.layer_kind(layer_idx)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "ssm":
        h = ssm_mod.mamba_forward(cfg, params["mixer"], h)
    elif cfg.use_mla:
        h = mla_mod.mla_forward(cfg, params["mixer"], h, positions)
    else:
        h = attn_mod.attn_forward(cfg, params["mixer"], h, positions)
    x = x + h
    if cfg.layer_has_moe(layer_idx):
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        h, metrics = moe_mod.moe_apply(cfg, params["ffn"], h)
        aux = {k: aux.get(k, 0.0) + v for k, v in metrics.items()}
        x = x + h
    elif cfg.d_ff:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = mlp_apply(params["ffn"], h)
        x = x + h
    x = shard(x, "batch", "seq", "embed")
    return x, aux


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def model_forward(
    cfg: ModelConfig,
    params,
    tokens: Optional[jnp.ndarray] = None,  # (B, S_text) int32
    embeds: Optional[jnp.ndarray] = None,  # (B, S_front, d) modality stub
    remat: str = "none",
):
    """Returns logits (B, S, vocab) and aux metrics (MoE losses)."""
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(dtype))
    if tokens is not None:
        parts.append(embedding_lookup(params["embed"], tokens, dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = shard(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    aux = {}
    for i, blk in enumerate(params["prologue"]):
        x, aux = _block_apply(cfg, i, blk, x, positions, aux)

    period = cfg.block_pattern_period

    def period_body(carry, period_params):
        x, aux = carry
        for j in range(period):
            layer_idx = cfg.first_k_dense + j  # kind pattern is periodic
            x, aux = _block_apply(
                cfg, layer_idx, period_params[f"b{j}"], x, positions, aux
            )
        return (x, aux), None

    # seed aux keys so the scan carry structure is static
    if cfg.moe_experts and any(
        cfg.layer_has_moe(i) for i in range(cfg.first_k_dense, cfg.n_layers)
    ):
        for k in ("aux_loss", "z_loss", "dropped_frac"):
            aux.setdefault(k, jnp.asarray(0.0, jnp.float32))

    body = _remat_wrap(period_body, remat)
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = head_apply(params["head"], x)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------


def _layer_cache_init(cfg: ModelConfig, layer_idx: int, batch: int, max_len: int, dtype):
    if cfg.layer_kind(layer_idx) == "ssm":
        return ssm_mod.mamba_cache_init(cfg, batch, dtype)
    if cfg.use_mla:
        return mla_mod.mla_cache_init(cfg, batch, max_len, dtype)
    return attn_mod.attn_cache_init(cfg, batch, max_len, dtype)


def cache_init(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked cache pytree matching the scanned parameter layout."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.block_pattern_period
    n_scan = (cfg.n_layers - cfg.first_k_dense) // period
    pro = [
        _layer_cache_init(cfg, i, batch, max_len, dtype)
        for i in range(cfg.first_k_dense)
    ]
    per_period = []
    for p in range(n_scan):
        blocks = {}
        for j in range(period):
            li = cfg.first_k_dense + p * period + j
            blocks[f"b{j}"] = _layer_cache_init(cfg, li, batch, max_len, dtype)
        per_period.append(blocks)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    return {"prologue": pro, "layers": stacked}


def _block_serve(cfg, layer_idx, params, x, positions, cache, pos, mode):
    kind = cfg.layer_kind(layer_idx)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "ssm":
        if mode == "prefill":
            h, cache = ssm_mod.mamba_prefill(cfg, params["mixer"], h, positions, cache)
        elif mode == "extend":
            h, cache = ssm_mod.mamba_extend(cfg, params["mixer"], h, cache, pos)
        else:
            h, cache = ssm_mod.mamba_decode(cfg, params["mixer"], h, cache, pos)
    elif cfg.use_mla:
        if mode == "prefill":
            h, cache = mla_mod.mla_prefill(cfg, params["mixer"], h, positions, cache)
        else:  # extend covers decode (S=1) and chunked prefill (S=chunk)
            h, cache = mla_mod.mla_extend(cfg, params["mixer"], h, cache, pos)
    else:
        if mode == "prefill":
            h, cache = attn_mod.attn_prefill(cfg, params["mixer"], h, positions, cache)
        else:
            h, cache = attn_mod.attn_extend(cfg, params["mixer"], h, cache, pos)
    x = x + h
    if cfg.layer_has_moe(layer_idx):
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        h, _ = moe_mod.moe_apply(cfg, params["ffn"], h)
        x = x + h
    elif cfg.d_ff:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = mlp_apply(params["ffn"], h)
        x = x + h
    x = shard(x, "batch", "seq", "embed")
    return x, cache


def _serve_pass(cfg: ModelConfig, params, x, positions, caches, pos, mode):
    period = cfg.block_pattern_period
    for i, blk in enumerate(params["prologue"]):
        x, caches["prologue"][i] = _block_serve(
            cfg, i, blk, x, positions, caches["prologue"][i], pos, mode
        )

    def body(x, xs):
        period_params, period_cache = xs
        for j in range(period):
            li = cfg.first_k_dense + j
            x, period_cache[f"b{j}"] = _block_serve(
                cfg, li, period_params[f"b{j}"], x, positions,
                period_cache[f"b{j}"], pos, mode,
            )
        return x, period_cache

    x, caches["layers"] = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return head_apply(params["head"], x), caches


def model_prefill(cfg: ModelConfig, params, tokens, caches, embeds=None):
    """Encode the prompt, fill caches; returns (last-position logits, caches)."""
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(dtype))
    if tokens is not None:
        parts.append(embedding_lookup(params["embed"], tokens, dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = shard(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    logits, caches = _serve_pass(cfg, params, x, positions, caches, pos=None, mode="prefill")
    return logits[:, -1], caches


def model_prefill_chunked(
    cfg: ModelConfig, params, tokens, caches, chunk: int, embeds=None
):
    """Chunked (Sarathi-style) prefill: process the prompt in fixed chunks.

    Bounds the per-step working set — MoE dispatch buffers, attention score
    blocks and activation residuals scale with the CHUNK, not the prompt:
    the un-chunked 32k MoE prefill needed 322 GiB/chip of temps; chunked at
    4k it is bounded by the train-shape working set.  SSM/conv states and
    KV caches carry across chunks exactly (regression-tested vs the flat
    forward).
    """
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(dtype))
    if tokens is not None:
        parts.append(embedding_lookup(params["embed"], tokens, dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = shard(x, "batch", "seq", "embed")
    b, s, d = x.shape
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    def body(caches, i):
        xc = jax.lax.dynamic_slice(x, (0, i * chunk, 0), (b, chunk, d))
        pos = i * chunk
        positions = pos + jnp.broadcast_to(jnp.arange(chunk), (b, chunk)).astype(
            jnp.int32
        )
        logits, caches = _serve_pass(
            cfg, params, xc, positions, caches, pos=pos, mode="extend"
        )
        return caches, logits[:, -1]

    caches, last_logits = jax.lax.scan(body, caches, jnp.arange(n_chunks))
    return last_logits[-1], caches


def model_decode(cfg: ModelConfig, params, token, caches, pos):
    """One decode step. token: (B,) int32; pos: () int32 absolute position."""
    dtype = jnp.dtype(cfg.dtype)
    x = embedding_lookup(params["embed"], token[:, None], dtype)
    x = shard(x, "batch", "seq", "embed")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(pos, (b, s)).astype(jnp.int32)
    logits, caches = _serve_pass(cfg, params, x, positions, caches, pos=pos, mode="decode")
    return logits[:, -1], caches
