from repro.models.config import ModelConfig
from repro.models.model import (
    cache_init,
    model_decode,
    model_forward,
    model_init,
    model_prefill,
)

__all__ = [
    "ModelConfig",
    "cache_init",
    "model_decode",
    "model_forward",
    "model_init",
    "model_prefill",
]
