"""Mamba-2 mixer via the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

Training/prefill uses the chunked SSD form: intra-chunk duality (quadratic
within a chunk — MXU-friendly batched matmuls) + a sequential inter-chunk
state recurrence (lax.scan over L/chunk steps).  Decode is the O(1)
recurrent step on the (B, H, P, N) state — which is why the ssm family runs
the long_500k shape that quadratic attention cannot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_init, truncated_normal_init


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def mamba_init(cfg: ModelConfig, key):
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    keys = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    cd = _conv_dim(cfg)
    return {
        "in_proj": truncated_normal_init(keys[0], (d, proj_out), 1.0),
        "conv_w": 0.1 * jax.random.normal(keys[1], (cfg.ssm_conv, cd), jnp.float32),
        "conv_b": jnp.zeros((cd,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": rmsnorm_init(di),
        "out_proj": truncated_normal_init(keys[2], (di, d), 1.0),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * ns], axis=-1)
    return z, xbc, dt  # (..., di), (..., di + 2ns), (..., nh)


def _causal_conv(params, xbc, conv_state=None):
    """Depthwise causal conv over the (B, L, conv_dim) channel block.

    conv_state: (B, K-1, conv_dim) holding the previous inputs (decode).
    Returns (out, new_conv_state).
    """
    k = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, L + K - 1, cd)
    w = params["conv_w"].astype(xbc.dtype)
    out = sum(full[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    out = jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))
    new_state = full[:, -(k - 1) :, :]
    return out, new_state


def _ssd_chunked(cfg: ModelConfig, x, dt, b_mat, c_mat, a, initial_state=None):
    """Chunked SSD scan.

    x: (B, L, H, P) — already the post-conv branch reshaped to heads;
    dt: (B, L, H) positive step sizes; a: (B, L, H) = A*dt (negative);
    b_mat/c_mat: (B, L, N) shared across heads (ngroups=1).
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    B, L, H, P = x.shape
    N = b_mat.shape[-1]
    Q = min(cfg.ssm_chunk, L)
    orig_len = L
    if L % Q:
        # pad the tail: a=0 (decay 1) and x=0 leave the recurrent state exact
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        L = L + pad
    nc = L // Q
    f32 = jnp.float32

    xe = (x * dt[..., None]).astype(f32).reshape(B, nc, Q, H, P)
    a = a.astype(f32).reshape(B, nc, Q, H)
    bm = b_mat.astype(f32).reshape(B, nc, Q, N)
    cm = c_mat.astype(f32).reshape(B, nc, Q, N)

    xe = shard(xe, "batch", None, None, "ssm_heads", None)
    a_cs = jnp.cumsum(a, axis=2)  # inclusive within-chunk cumsum
    # intra-chunk (dual quadratic form): L[i, j] = exp(a_cs[i] - a_cs[j]), i >= j
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE exp: the upper triangle has large positive seg whose exp
    # overflows, and inf * 0 poisons the backward pass with NaNs
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)
    # the (Q, Q, H) decay block is the largest SSD intermediate — keep it
    # sharded over batch and heads or it replicates (iteration-0 dry-run:
    # jamba train needed 777 GiB/chip)
    decay = shard(decay, "batch", None, None, None, "ssm_heads")
    scores = jnp.einsum("bcin,bcjn->bcij", cm, bm)  # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, xe)

    # per-chunk end states: sum_j B_j (x_j dt_j) exp(a_cs[-1] - a_cs[j])
    end_decay = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bm, end_decay, xe)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))  # (B,nc,H)
    init = (
        jnp.zeros((B, H, P, N), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def scan_fn(state, inp):
        s_c, g_c = inp  # (B,H,P,N), (B,H)
        out_prev = state
        state = state * g_c[:, :, None, None] + s_c
        return state, out_prev

    xs = (
        jnp.moveaxis(chunk_states, 1, 0),  # (nc, B, H, P, N)
        jnp.moveaxis(chunk_decay, 1, 0),  # (nc, B, H)
    )
    final_state, prev_states = jax.lax.scan(scan_fn, init, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    # inter-chunk contribution: C_i (state at chunk start) decayed to i
    in_decay = jnp.exp(a_cs)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cm, prev_states, in_decay)

    y = (y_diag + y_off).reshape(B, L, H, P)
    return y[:, :orig_len], final_state


def mamba_forward(cfg: ModelConfig, params, x, positions=None):
    y, _ = _mamba_seq(cfg, params, x, conv_state=None, ssm_state=None)
    return y


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype):
    return {
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dtype),
    }


def mamba_prefill(cfg: ModelConfig, params, x, positions, cache):
    y, new_cache = _mamba_seq(cfg, params, x, conv_state=None, ssm_state=None)
    return y, new_cache


def mamba_extend(cfg: ModelConfig, params, x, cache, pos=None):
    """Chunked prefill / multi-token decode: carry conv+ssm state forward."""
    return _mamba_seq(cfg, params, x, conv_state=cache["conv"], ssm_state=cache["ssm"])


def _mamba_seq(cfg: ModelConfig, params, x, conv_state, ssm_state):
    b, l, _ = x.shape
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dtype = x.dtype
    proj = x @ params["in_proj"].astype(dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, new_conv = _causal_conv(params, xbc, conv_state)
    xs, bc = jnp.split(xbc, [di], axis=-1)
    b_mat, c_mat = jnp.split(bc, [ns], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"]
    )  # (B,L,H)
    dt = shard(dt, "batch", "seq", "ssm_heads")
    a = -jnp.exp(params["a_log"])[None, None, :] * dt  # (B,L,H)
    xh = xs.reshape(b, l, nh, hp)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    y, final_state = _ssd_chunked(cfg, xh, dt, b_mat, c_mat, a, ssm_state)
    y = shard(y, "batch", "seq", "ssm_heads", None)
    y = y.astype(dtype) + params["d_skip"].astype(dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dtype)
    return out, {"ssm": final_state, "conv": new_conv}


def mamba_decode(cfg: ModelConfig, params, x, cache, pos=None):
    """One-token recurrent step. x: (B, 1, d_model)."""
    b, s, _ = x.shape
    assert s == 1
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    dtype = x.dtype
    proj = x @ params["in_proj"].astype(dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, new_conv = _causal_conv(params, xbc, cache["conv"])
    xs, bc = jnp.split(xbc, [di], axis=-1)
    b_mat, c_mat = jnp.split(bc, [ns], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    ga = jnp.exp(-jnp.exp(params["a_log"])[None, :] * dt)  # (B,H)
    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    bm = b_mat[:, 0].astype(jnp.float32)  # (B,N)
    cm = c_mat[:, 0].astype(jnp.float32)
    state = cache["ssm"] * ga[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xh * dt[:, :, None], bm
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cm)
    y = y.astype(dtype) + params["d_skip"].astype(dtype)[None, :, None] * xh.astype(
        dtype
    )
    y = y.reshape(b, 1, di)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dtype)
    return out, {"ssm": state, "conv": new_conv}
