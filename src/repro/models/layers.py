"""Shared neural layers (pure-functional JAX, no framework dependency).

Parameters are plain nested dicts of jnp arrays; every module exposes
``init(cfg, key, ...) -> params`` and a pure ``apply``-style function.
Activations default to bf16 with fp32 norms/softmax/logits (standard mixed
precision); parameters are fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# --- RMSNorm ------------------------------------------------------------------


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dtype)


# --- Rotary embeddings --------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- Blockwise (online-softmax) attention ------------------------------------
#
# Flash-attention-style streaming over KV blocks keeps the peak activation
# footprint at O(S * block) instead of O(S^2) — required for the 32k/500k
# shapes to pass the dry-run memory analysis, and the TPU-idiomatic way to
# run long attention (the MXU consumes (q_block, kv_block) tiles).


def blockwise_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, hd)
    *,
    causal: bool,
    q_offset=0,  # scalar or traced: absolute position of q[0] (decode)
    kv_valid_len=None,  # mask KV positions >= this (ragged decode cache)
    kv_block: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    groups = h // hkv
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(hd))

    kv_block = min(kv_block, sk)
    if sk % kv_block:
        pad = kv_block - sk % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid_len = sk if kv_valid_len is None else kv_valid_len
        sk = sk + pad
    n_blocks = sk // kv_block

    # Keep the FLAT head axis everywhere: a (b, s, hkv, groups, hd) reshape
    # would split the TP-sharded head dim into two dims neither of which
    # divides the mesh axis, forcing GSPMD to all-gather Q (iteration-0
    # dry-run: +199 GiB of collectives on qwen3-32b).  Instead KV blocks are
    # repeated to the full head count inside the scan body — kv_block-sized,
    # so the repeat is cheap and head-sharded.
    qf = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, blk * kv_block, kv_block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, blk * kv_block, kv_block, axis=1)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        if groups > 1:
            kb = jnp.repeat(kb, groups, axis=2)  # (B, kv_block, H, hd)
            vb = jnp.repeat(vb, groups, axis=2)
        # scores: (B, Sq, H, kv_block)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, kb)
        kv_pos = blk * kv_block + jnp.arange(kv_block)
        mask = jnp.ones((sq, kv_block), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_valid_len is not None:
            mask &= (kv_pos < kv_valid_len)[None, :]
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, :], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vb)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, sq, h), -jnp.inf, jnp.float32),
        jnp.zeros((b, sq, h), jnp.float32),
        jnp.zeros((b, sq, h, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


# --- SwiGLU MLP ---------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal_init(k1, (d_model, d_ff), 1.0),
        "w_up": truncated_normal_init(k2, (d_model, d_ff), 1.0),
        "w_down": truncated_normal_init(k3, (d_ff, d_model), 1.0),
    }


def mlp_apply(params, x):
    dtype = x.dtype
    gate = x @ params["w_gate"].astype(dtype)
    up = x @ params["w_up"].astype(dtype)
    return (jax.nn.silu(gate) * up) @ params["w_down"].astype(dtype)


# --- Embedding / head ---------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int):
    return {"table": 0.02 * jax.random.normal(key, (vocab, d_model), jnp.float32)}


def embedding_lookup(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def head_init(key, d_model: int, vocab: int):
    return {"w": truncated_normal_init(key, (d_model, vocab), 1.0)}


def head_apply(params, x):
    # logits in fp32 for a stable softmax/cross-entropy
    return x.astype(jnp.float32) @ params["w"].astype(jnp.float32)
