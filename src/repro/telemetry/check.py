"""Validate telemetry artifacts (CI smoke checker).

Usage (exit 0 iff every requested artifact is well-formed)::

    PYTHONPATH=src python -m repro.telemetry.check \\
        --trace /tmp/t.json --devices 4 --expect-flow \\
        --metrics /tmp/m.jsonl

Checks the structural contracts the rest of the tooling relies on:
Chrome traces must carry the required ``ph``/``ts``/``pid``/``tid`` keys,
balanced ``B``/``E`` span stacks per lane, one named lane per device, and
(optionally) at least one matched ``s``/``f`` flow pair — migrations,
reroutes, or (with ``--expect-flow-name``) a specific flow such as a
device-loss evacuation.  Metrics files must be one JSON object per line,
each with the recorder's ``kind``/``name``/``ts``/``seq`` envelope.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

PHASES_NEEDING_TS = {"B", "E", "i", "X", "C", "s", "f", "t"}
EVENT_KINDS = {
    "counter",
    "gauge",
    "hist",
    "instant",
    "span_begin",
    "span_end",
    "flow_begin",
    "flow_end",
}


def check_trace(
    path: str,
    n_devices: Optional[int] = None,
    expect_flow: bool = False,
    expect_flow_name: Optional[str] = None,
) -> List[str]:
    """Return a list of problems with the Chrome trace at ``path``."""
    problems: List[str] = []
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        return [f"trace {path}: unreadable ({e})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"trace {path}: no traceEvents array"]

    lane_names: Dict[Any, str] = {}
    stacks: Dict[Any, List[str]] = {}
    flow_starts: Dict[Any, str] = {}
    flow_ends: Dict[Any, str] = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing 'ph'")
            continue
        if "pid" not in e or "tid" not in e:
            problems.append(f"event {i} (ph={ph}): missing pid/tid")
            continue
        if ph == "M":
            if e.get("name") == "thread_name":
                lane_names[(e["pid"], e["tid"])] = e["args"]["name"]
            continue
        if "ts" not in e:
            problems.append(f"event {i} (ph={ph}, name={e.get('name')}): missing ts")
            continue
        key = (e["pid"], e["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append(e.get("name", "?"))
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                problems.append(
                    f"event {i}: E for {e.get('name')!r} on lane {key} "
                    "with no open span"
                )
            else:
                stack.pop()
        elif ph == "s":
            flow_starts[e.get("id")] = e.get("name", "?")
        elif ph == "f":
            flow_ends[e.get("id")] = e.get("name", "?")
    for key, stack in stacks.items():
        if stack:
            problems.append(f"lane {key}: unclosed spans {stack}")

    if n_devices is not None:
        names = set(lane_names.values())
        for d in range(n_devices):
            if f"device {d}" not in names:
                problems.append(
                    f"no 'device {d}' lane (found: {sorted(names)})"
                )
    if expect_flow:
        matched = set(flow_starts) & set(flow_ends)
        if not matched:
            problems.append(
                f"no matched s/f flow pair (starts={len(flow_starts)}, "
                f"ends={len(flow_ends)})"
            )
    if expect_flow_name is not None:
        matched_names = {
            flow_starts[i] for i in set(flow_starts) & set(flow_ends)
        }
        if expect_flow_name not in matched_names:
            problems.append(
                f"no matched flow named {expect_flow_name!r} "
                f"(found: {sorted(matched_names)})"
            )
    unmatched = set(flow_starts) ^ set(flow_ends)
    if unmatched:
        problems.append(f"unpaired flow ids: {sorted(unmatched)[:8]}")
    return problems


def check_metrics(path: str) -> List[str]:
    """Return a list of problems with the metrics JSONL at ``path``."""
    problems: List[str] = []
    try:
        fh = open(path, encoding="utf-8")
    except OSError as e:
        return [f"metrics {path}: unreadable ({e})"]
    n = 0
    with fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError as err:
                problems.append(f"line {lineno}: not JSON ({err})")
                continue
            n += 1
            for key in ("kind", "name", "ts", "seq"):
                if key not in e:
                    problems.append(f"line {lineno}: missing {key!r}")
            kind = e.get("kind")
            if kind is not None and kind not in EVENT_KINDS:
                problems.append(f"line {lineno}: unknown kind {kind!r}")
    if n == 0:
        problems.append(f"metrics {path}: no events")
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None, help="Chrome trace JSON to validate")
    ap.add_argument("--metrics", default=None, help="metrics JSONL to validate")
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="require a named lane per device in the trace",
    )
    ap.add_argument(
        "--expect-flow",
        action="store_true",
        help="require >=1 matched s/f flow pair (migration or reroute)",
    )
    ap.add_argument(
        "--expect-flow-name",
        default=None,
        metavar="NAME",
        help="require >=1 matched flow pair with this exact name "
        "(e.g. service.evacuate, service.migrate, service.reroute)",
    )
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")
    problems: List[str] = []
    if args.trace:
        problems += check_trace(
            args.trace,
            n_devices=args.devices,
            expect_flow=args.expect_flow,
            expect_flow_name=args.expect_flow_name,
        )
    if args.metrics:
        problems += check_metrics(args.metrics)
    for p in problems:
        print(f"CHECK FAIL: {p}", file=sys.stderr)
    if not problems:
        checked = " and ".join(
            p for p in (args.trace, args.metrics) if p
        )
        print(f"telemetry check OK: {checked}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
