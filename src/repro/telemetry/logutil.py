"""Logging setup shared by the CLIs and selftests.

One convention everywhere: human-readable progress goes through
``logging`` (so ``--quiet``/``--verbose`` work uniformly), while
machine-readable ``RESULT_JSON:`` lines stay bare ``print()`` calls —
they are a wire format consumed by CI/pytest subprocess harnesses and
must remain byte-identical regardless of verbosity
(``tests/test_no_print.py`` enforces exactly this split).
"""

from __future__ import annotations

import argparse
import logging
import sys


def add_verbosity_flags(ap: argparse.ArgumentParser) -> None:
    """Attach the standard ``--quiet`` / ``--verbose`` pair."""
    g = ap.add_mutually_exclusive_group()
    g.add_argument(
        "-q",
        "--quiet",
        action="store_true",
        help="suppress progress output (warnings and RESULT_JSON lines only)",
    )
    g.add_argument(
        "-v",
        "--verbose",
        action="store_true",
        help="debug-level progress output",
    )


def setup_logging(
    quiet: bool = False, verbose: bool = False, name: str = "repro"
) -> logging.Logger:
    """Configure and return the CLI logger (message-only format, stdout).

    Messages go to stdout (not stderr) so existing shell pipelines around
    the launchers keep seeing the same stream they did when these were
    ``print()`` calls.
    """
    level = (
        logging.WARNING if quiet else logging.DEBUG if verbose else logging.INFO
    )
    logger = logging.getLogger(name)
    logger.setLevel(level)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter("%(message)s"))
        logger.addHandler(handler)
    logger.propagate = False
    return logger
