"""Derived load/idle views: per-device occupancy and imbalance timelines.

The sharded service records a ``service.n_live`` gauge per device at every
executed iteration (host-side, from the fused dispatch's read-back metrics
— see DESIGN.md §8).  This module turns that event stream into the views
the paper plots:

- :func:`occupancy_from_events` — the raw per-device live-slot timeline;
- :func:`idle_fraction` — per-device fraction of slot-iterations idle
  (1 - occupied/total), the live-service analogue of paper Fig. 4b;
- :func:`imbalance` / :func:`imbalance_series` / :func:`mean_imbalance` —
  the exact ``1 - mean/max`` work-imbalance statistic the offline
  ``benchmarks/fig4b_idle.py`` script reports (via
  ``DistributedResult.mean_imbalance``), so live-telemetry numbers and
  offline-benchmark numbers are the same computation on the same series.

Everything is pure Python over the recorded events — no jax, no numpy —
so it is usable on a metrics JSONL file long after the run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Sequence

#: gauge name the service scheduler records per device per iteration
N_LIVE = "service.n_live"
#: gauge name the distributed driver records per iteration (scalar)
WORK_IMB = "dist.work_imb"


def imbalance(per_device_work: Sequence[float]) -> float:
    """Paper Fig. 4b idle-time proxy for one iteration: ``1 - mean/max``.

    Matches ``make_dist_step`` in :mod:`repro.core.distributed`:
    ``where(max > 0, 1 - (sum/n)/max(max, 1), 0)``.  0 = perfectly
    balanced, -> 1 = one device does all the work.
    """
    n = len(per_device_work)
    if n == 0:
        return 0.0
    mx = max(per_device_work)
    if mx <= 0:
        return 0.0
    return 1.0 - (sum(per_device_work) / n) / max(mx, 1)


@dataclass
class Timeline:
    """Per-device series sampled at iteration boundaries.

    ``values[t][d]`` is device ``d``'s sample at ``iterations[t]``.
    """

    devices: List[int]
    iterations: List[int]
    values: List[List[float]]

    def series(self, device: int) -> List[float]:
        j = self.devices.index(device)
        return [row[j] for row in self.values]


def occupancy_from_events(
    events: Iterable[Dict[str, Any]], name: str = N_LIVE
) -> Timeline:
    """Build the per-device occupancy timeline from recorded gauge events.

    Expects gauges named ``name`` with ``lane`` = device index and an
    ``it`` attr = global iteration number (what the scheduler records).
    """
    samples: Dict[int, Dict[int, float]] = {}
    devices: set = set()
    for e in events:
        if e.get("kind") != "gauge" or e.get("name") != name:
            continue
        it = int(e["it"])
        dev = int(e["lane"])
        devices.add(dev)
        samples.setdefault(it, {})[dev] = float(e["value"])
    devs = sorted(devices)
    its = sorted(samples)
    values = [[samples[it].get(d, 0.0) for d in devs] for it in its]
    return Timeline(devices=devs, iterations=its, values=values)


def idle_fraction(
    timeline: Timeline, slots_per_device: int
) -> Dict[int, float]:
    """Per-device idle fraction over the run: 1 - occupied/(iters*slots).

    A slot-iteration is *occupied* when the slot held a live (admitted,
    not yet converged) problem at that iteration; everything else —
    empty slots, slots whose problem already finished — is idle capacity.
    """
    n_it = len(timeline.iterations)
    if n_it == 0:
        return {d: 0.0 for d in timeline.devices}
    out = {}
    for j, d in enumerate(timeline.devices):
        occupied = sum(row[j] for row in timeline.values)
        out[d] = 1.0 - occupied / (n_it * slots_per_device)
    return out


def imbalance_series(timeline: Timeline) -> List[float]:
    """Per-iteration Fig. 4b imbalance over the timeline's device rows."""
    return [imbalance(row) for row in timeline.values]


def mean_imbalance(timeline: Timeline) -> float:
    series = imbalance_series(timeline)
    if not series:
        return 0.0
    return sum(series) / len(series)


def hist_values_from_events(
    events: Iterable[Dict[str, Any]], name: str
) -> List[float]:
    """All values of histogram ``name`` recorded in an event stream.

    The offline (metrics-JSONL) half of the latency views: feed the result
    to :func:`repro.telemetry.core.quantile` for the same p50/p99 the live
    recorder's ``quantile`` reports — e.g. the ``service.dispatch_wall_s``
    / ``service.queue_wait_s`` histograms the scheduler records at dispatch
    boundaries, which the perf report renders.
    """
    return [
        float(e["value"])
        for e in events
        if e.get("kind") == "hist" and e.get("name") == name
    ]


def mean_work_imbalance_from_events(
    events: Iterable[Dict[str, Any]], name: str = WORK_IMB
) -> float:
    """Mean of the distributed driver's recorded per-iteration imbalance.

    On the same run this equals ``DistributedResult.mean_imbalance()``
    exactly — both are the arithmetic mean of the same ``work_imb``
    read-back values (asserted in ``tests/test_telemetry.py``).
    """
    vals = [
        float(e["value"])
        for e in events
        if e.get("kind") == "gauge" and e.get("name") == name
    ]
    if not vals:
        return 0.0
    return sum(vals) / len(vals)
