"""Event sinks: where Recorder events go.

- :class:`JsonlSink` — one JSON object per line, append-only; the
  ``--metrics PATH`` CLI flag attaches one of these.
- :class:`MemorySink` — in-memory ring buffer; tests and the ``--trace``
  export path use it (Chrome trace export needs the whole event stream).
- :func:`summary_table` — end-of-run plain-text aggregate table rendered
  from a Recorder's in-memory aggregates (``--telemetry-summary``).
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Dict, List, Optional


class _Encoder(json.JSONEncoder):
    """Tolerate numpy scalars/arrays without importing numpy here."""

    def default(self, o: Any) -> Any:
        item = getattr(o, "item", None)
        if item is not None and getattr(o, "shape", None) in ((), None):
            return item()
        tolist = getattr(o, "tolist", None)
        if tolist is not None:
            return tolist()
        return repr(o)


class JsonlSink:
    """Append events to ``path`` as JSON Lines."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh = open(path, "w", encoding="utf-8")

    def emit(self, event: Dict[str, Any]) -> None:
        self._fh.write(json.dumps(event, cls=_Encoder) + "\n")

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL metrics file back into a list of event dicts."""
    events = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


class MemorySink:
    """Keep the last ``maxlen`` events in memory (``None`` = unbounded)."""

    def __init__(self, maxlen: Optional[int] = None) -> None:
        self.events: deque = deque(maxlen=maxlen)

    def emit(self, event: Dict[str, Any]) -> None:
        # Copy: the recorder reuses nothing, but callers may mutate attrs
        # dicts they passed in after the fact.
        self.events.append(dict(event))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


def summary_table(recorder: Any) -> str:
    """Render the recorder's aggregates as an aligned plain-text table."""
    lines: List[str] = []

    def section(title: str, rows: List[List[str]], header: List[str]) -> None:
        if not rows:
            return
        widths = [
            max(len(h), *(len(r[i]) for r in rows)) for i, h in enumerate(header)
        ]
        lines.append(title)
        lines.append("  " + "  ".join(h.ljust(w) for h, w in zip(header, widths)))
        for r in rows:
            lines.append("  " + "  ".join(c.ljust(w) for c, w in zip(r, widths)))
        lines.append("")

    section(
        "counters",
        [[k, f"{v:g}"] for k, v in sorted(recorder.counters.items())],
        ["name", "total"],
    )
    section(
        "gauges (last)",
        [[k, f"{v:g}"] for k, v in sorted(recorder.gauges.items())],
        ["name", "value"],
    )
    section(
        "histograms",
        [
            [
                k,
                f"{int(h['count'])}",
                f"{h['sum'] / max(h['count'], 1):.4g}",
                f"{h['min']:.4g}",
                f"{recorder.quantile(k, 0.5):.4g}",
                f"{recorder.quantile(k, 0.99):.4g}",
                f"{h['max']:.4g}",
            ]
            for k, h in sorted(recorder.hists.items())
        ],
        ["name", "count", "mean", "min", "p50", "p99", "max"],
    )
    section(
        "spans",
        [
            [
                k,
                f"{int(t['count'])}",
                f"{t['total_s']:.4f}",
                f"{1e3 * t['total_s'] / max(t['count'], 1):.3f}",
            ]
            for k, t in sorted(recorder.span_totals.items())
        ],
        ["name", "count", "total_s", "mean_ms"],
    )
    if not lines:
        return "(no telemetry recorded)"
    return "\n".join(lines).rstrip()
