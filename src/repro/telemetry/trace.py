"""Chrome trace-event export (chrome://tracing / https://ui.perfetto.dev).

Maps a recorded event stream (see :mod:`repro.telemetry.core` for the
schema) onto the Trace Event Format:

- one *thread lane* per device plus a ``scheduler`` lane (lane ``None``);
- ``span_begin``/``span_end`` → ``B``/``E`` duration events (compile,
  dispatch, admit, collect, checkpoint, ...);
- ``instant`` → ``i`` events;
- ``gauge``/``counter`` → ``C`` counter tracks (per-device gauges get one
  track per lane, e.g. the ``service.n_live`` occupancy timelines);
- ``flow_begin``/``flow_end`` → a pair of 1 µs ``X`` slices joined by
  ``s``/``f`` flow arrows — slot migrations and reroutes draw as arrows
  from the source device lane to the destination lane.

Timestamps are converted to microseconds relative to the first event so
traces start at t=0 regardless of the monotonic-clock epoch.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

PID = 1
SCHEDULER_TID = 0


def _tid(lane: Optional[int]) -> int:
    return SCHEDULER_TID if lane is None else int(lane) + 1


def _attrs(event: Dict[str, Any]) -> Dict[str, Any]:
    skip = {"kind", "name", "ts", "seq", "lane", "depth", "dur", "id"}
    return {k: v for k, v in event.items() if k not in skip}


def to_chrome(
    events: Iterable[Dict[str, Any]], process_name: str = "repro-quad"
) -> Dict[str, Any]:
    """Build a Trace Event Format dict from a recorded event stream."""
    evs = sorted(events, key=lambda e: (e.get("ts", 0.0), e.get("seq", 0)))
    t0 = evs[0]["ts"] if evs else 0.0
    out: List[Dict[str, Any]] = [
        {
            "ph": "M",
            "pid": PID,
            "tid": SCHEDULER_TID,
            "name": "process_name",
            "args": {"name": process_name},
        }
    ]
    lanes = {None}
    for e in evs:
        lanes.add(e.get("lane"))
    for lane in sorted(lanes, key=lambda x: -1 if x is None else int(x)):
        out.append(
            {
                "ph": "M",
                "pid": PID,
                "tid": _tid(lane),
                "name": "thread_name",
                "args": {
                    "name": "scheduler" if lane is None else f"device {lane}"
                },
            }
        )

    def us(ts: float) -> float:
        return round((ts - t0) * 1e6, 3)

    for e in evs:
        kind = e["kind"]
        name = e["name"]
        ts = us(e["ts"])
        tid = _tid(e.get("lane"))
        if kind == "span_begin":
            out.append(
                {
                    "ph": "B",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "name": name,
                    "cat": name.split(".", 1)[0],
                    "args": _attrs(e),
                }
            )
        elif kind == "span_end":
            out.append(
                {
                    "ph": "E",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "name": name,
                    "cat": name.split(".", 1)[0],
                    "args": _attrs(e),
                }
            )
        elif kind == "instant":
            out.append(
                {
                    "ph": "i",
                    "s": "t",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "name": name,
                    "cat": name.split(".", 1)[0],
                    "args": _attrs(e),
                }
            )
        elif kind == "gauge":
            track = name if e.get("lane") is None else f"{name}[{e['lane']}]"
            out.append(
                {
                    "ph": "C",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "name": track,
                    "args": {"value": e["value"]},
                }
            )
        elif kind == "counter":
            out.append(
                {
                    "ph": "C",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "name": name,
                    "args": {"total": e["total"]},
                }
            )
        elif kind == "flow_begin":
            # A visible anchor slice on the source lane plus the flow start.
            out.append(
                {
                    "ph": "X",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "dur": 1,
                    "name": name,
                    "cat": "flow",
                    "args": _attrs(e),
                }
            )
            out.append(
                {
                    "ph": "s",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts,
                    "id": e["id"],
                    "name": name,
                    "cat": "flow",
                }
            )
        elif kind == "flow_end":
            # Offset the destination anchor 1 µs so the arrow has extent
            # even when both halves were recorded at the same host instant.
            out.append(
                {
                    "ph": "X",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts + 1,
                    "dur": 1,
                    "name": name,
                    "cat": "flow",
                    "args": _attrs(e),
                }
            )
            out.append(
                {
                    "ph": "f",
                    "bp": "e",
                    "pid": PID,
                    "tid": tid,
                    "ts": ts + 1,
                    "id": e["id"],
                    "name": name,
                    "cat": "flow",
                }
            )
        # "hist" events carry no natural trace geometry; their aggregates
        # surface in the summary table instead.
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(
    path: str,
    events: Iterable[Dict[str, Any]],
    process_name: str = "repro-quad",
) -> Dict[str, Any]:
    """Serialize :func:`to_chrome` of ``events`` to ``path``; returns it."""
    doc = to_chrome(events, process_name=process_name)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)
    return doc
