"""Dependency-free observability for the quadrature serving stack.

See DESIGN.md §8.  The subsystem is host-side only: events are recorded
strictly at dispatch boundaries, never inside traced code, so telemetry
on/off cannot perturb any compiled computation (bit-parity is asserted in
``tests/test_telemetry.py``).

- :mod:`~repro.telemetry.core` — :class:`Recorder` (counters, gauges,
  histograms, nestable spans, flows) and the no-op :data:`NULL`;
- :mod:`~repro.telemetry.sinks` — JSONL / in-memory sinks, summary table;
- :mod:`~repro.telemetry.trace` — Chrome trace-event (Perfetto) export;
- :mod:`~repro.telemetry.loadview` — per-device occupancy / idle-fraction
  / Fig. 4b imbalance timelines derived from recorded events;
- :mod:`~repro.telemetry.stats` — the typed :class:`ServiceStats` schema;
- :mod:`~repro.telemetry.check` — artifact validator (CI smoke checker);
- :mod:`~repro.telemetry.logutil` — shared CLI logging setup.
"""

from repro.telemetry.core import NULL, NullRecorder, Recorder, quantile
from repro.telemetry.sinks import JsonlSink, MemorySink, read_jsonl, summary_table
from repro.telemetry.stats import ServiceStats
from repro.telemetry.trace import to_chrome, write_chrome_trace

__all__ = [
    "NULL",
    "NullRecorder",
    "Recorder",
    "quantile",
    "JsonlSink",
    "MemorySink",
    "read_jsonl",
    "summary_table",
    "ServiceStats",
    "to_chrome",
    "write_chrome_trace",
]
