"""ServiceStats: the one typed schema for service host-loop counters.

Before this existed, ``_ZERO_STATS`` was a dict literal in
``service/scheduler.py`` that ``service/routing.py`` imported, extended
with ``reroutes``, and merged by hand — so a counter added to one pool
silently vanished from the graceful aggregate (the merge loop only knew
the keys it was written against).  Here the schema is a frozen-field
dataclass: scheduler, graceful router, and the checkpoint meta sidecar
all share it, ``merge`` is field-wise by construction, and an unknown key
in a restored checkpoint is a loud error instead of silent drift.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class ServiceStats:
    """Host-loop counters for one service run (see DESIGN.md §8).

    All counters are integers; ``as_dict`` is the compatibility view
    exposed as ``BatchScheduler.last_stats`` / ``GracefulScheduler.last_stats``.
    """

    iterations: int = 0  # fleet iterations executed (all slots advance together)
    dispatches: int = 0  # fused engine launches
    admissions: int = 0  # requests admitted into slots (incl. retries)
    collections: int = 0  # terminal slots collected (any status)
    migrations: int = 0  # problems moved between devices by the rebalancer
    quarantines: int = 0  # slots collected with status "nonfinite"
    deadlines: int = 0  # slots evicted on an expired SLO
    checkpoints: int = 0  # service snapshots written
    reroutes: int = 0  # fallback re-admissions (graceful layer)
    dispatch_retries: int = 0  # dispatches re-attempted after a transient fault
    evacuations: int = 0  # slots recovered/re-admitted off a failed device
    mesh_shrinks: int = 0  # engine rebuilds onto a smaller surviving sub-mesh
    mesh_regrows: int = 0  # engine rebuilds back onto a restored device

    def add(self, name: str, n: int = 1) -> int:
        """Bump counter ``name`` by ``n``; unknown names raise AttributeError."""
        value = getattr(self, name) + n
        setattr(self, name, value)
        return value

    def merge(self, other: "ServiceStats") -> None:
        """Field-wise accumulate ``other`` into ``self``."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, obj: Dict[str, int]) -> "ServiceStats":
        """Rebuild from a stored dict (checkpoint meta sidecar).

        Missing keys default to 0 (snapshots written before a counter
        existed); unknown keys raise — that is the key-drift guard.
        """
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(
                f"unknown ServiceStats keys {sorted(unknown)}; known: {sorted(known)}"
            )
        return cls(**{k: int(v) for k, v in obj.items()})
