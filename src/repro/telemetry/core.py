"""Recorder: the host-side event spine of the telemetry subsystem.

Everything here is plain Python over plain dicts — no third-party
dependencies, no background threads, no global mutable registry.  A
:class:`Recorder` turns instrumentation calls (``count`` / ``gauge`` /
``observe`` / ``event`` / ``flow`` / ``span``) into *event dicts* pushed to
attached sinks (see :mod:`repro.telemetry.sinks`), while keeping cheap
in-memory aggregates for the end-of-run summary table.

The cardinal rule (DESIGN.md §8): **nothing is recorded inside traced
code.**  Instrumented call sites live strictly at dispatch boundaries —
after ``jax.device_get`` of a fused step's metrics, around ``engine.run``,
inside the host-side admission/collection loops.  The recorder therefore
never perturbs a jitted program: with telemetry on or off the compiled
computation is byte-for-byte the same, which is what makes the
recorder-on/off bit-parity tests in ``tests/test_telemetry.py`` possible.

Disabled telemetry costs one attribute lookup: call sites hold a
``Recorder`` reference (``NULL`` by default) and guard any non-trivial
bookkeeping with ``if rec.enabled:``.  :class:`NullRecorder` methods are
no-ops returning cached singletons, so even unguarded calls are a few
hundred nanoseconds.

Event schema (one dict per event; sinks serialize it verbatim)::

    {"kind": "counter" | "gauge" | "hist" | "instant"
             | "span_begin" | "span_end" | "flow_begin" | "flow_end",
     "name": str,          # dotted taxonomy, e.g. "service.dispatch"
     "ts":   float,        # seconds on the recorder clock (monotonic)
     "seq":  int,          # global order tiebreaker (clock may be coarse)
     "lane": int | None,   # device index, or None for the scheduler lane
     ...}                  # kind-specific payload (value, attrs, id, dur)

The clock is injectable (``Recorder(clock=fake)``) so tests assert exact
span durations and orderings deterministically.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple


#: Per-histogram raw-sample retention cap.  ``observe`` keeps the first N
#: values so :meth:`Recorder.quantile` can answer p50/p99 exactly for runs
#: of realistic length (a dispatch-boundary histogram collects one value
#: per dispatch — tens of thousands at most); past the cap new values still
#: update count/sum/min/max but no longer enter the quantile sample.  The
#: first-N policy is deterministic, which the bit-parity and fake-clock
#: tests rely on.
HIST_SAMPLE_CAP = 16384


def quantile(values: List[float], q: float) -> float:
    """Linear-interpolation quantile of ``values`` (q in [0, 1]).

    Plain Python (no numpy) so offline consumers — the perf report reading
    a metrics JSONL long after the run — share the exact computation the
    live recorder uses.
    """
    if not values:
        return 0.0
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    s = sorted(values)
    pos = q * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


class Recorder:
    """Collects structured telemetry events and aggregates.

    Parameters
    ----------
    sinks:
        Iterable of sink objects with an ``emit(event: dict)`` method (and
        optionally ``flush()`` / ``close()``).  See
        :mod:`repro.telemetry.sinks`.
    clock:
        Zero-arg callable returning seconds.  Defaults to
        :func:`time.monotonic`; inject a fake for deterministic tests.
    """

    enabled = True

    def __init__(
        self,
        sinks: Tuple[Any, ...] = (),
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.sinks: List[Any] = list(sinks)
        self.clock = clock
        self._seq = 0
        self._flow_id = 0
        self._span_depth = 0
        # Aggregates for the summary table / stats compatibility views.
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Dict[str, float]] = {}
        self.hist_samples: Dict[str, List[float]] = {}
        self.span_totals: Dict[str, Dict[str, float]] = {}

    # -- plumbing ---------------------------------------------------------

    def add_sink(self, sink: Any) -> None:
        self.sinks.append(sink)

    def _emit(self, event: Dict[str, Any]) -> None:
        event["seq"] = self._seq
        self._seq += 1
        for sink in self.sinks:
            sink.emit(event)

    def flush(self) -> None:
        for sink in self.sinks:
            fn = getattr(sink, "flush", None)
            if fn is not None:
                fn()

    def close(self) -> None:
        for sink in self.sinks:
            fn = getattr(sink, "close", None)
            if fn is not None:
                fn()

    # -- metrics ----------------------------------------------------------

    def count(
        self, name: str, n: float = 1, lane: Optional[int] = None, **attrs: Any
    ) -> None:
        """Increment counter ``name`` by ``n`` and emit a counter event.

        The event carries the running ``total`` so trace export can draw a
        cumulative counter track without replaying the stream.
        """
        total = self.counters.get(name, 0) + n
        self.counters[name] = total
        # attrs first throughout: a caller attr must never overwrite the
        # envelope ("kind", "ts", ...) — a collision would silently turn the
        # event into an unknown type that every consumer drops
        self._emit(
            {
                **attrs,
                "kind": "counter",
                "name": name,
                "ts": self.clock(),
                "lane": lane,
                "n": n,
                "total": total,
            }
        )

    def gauge(
        self, name: str, value: float, lane: Optional[int] = None, **attrs: Any
    ) -> None:
        """Record the current value of ``name`` (last-write-wins aggregate)."""
        key = name if lane is None else f"{name}[{lane}]"
        self.gauges[key] = value
        self._emit(
            {
                **attrs,
                "kind": "gauge",
                "name": name,
                "ts": self.clock(),
                "lane": lane,
                "value": value,
            }
        )

    def observe(
        self, name: str, value: float, lane: Optional[int] = None, **attrs: Any
    ) -> None:
        """Add ``value`` to histogram ``name`` (count/sum/min/max stats)."""
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = {
                "count": 0,
                "sum": 0.0,
                "min": float("inf"),
                "max": float("-inf"),
            }
        h["count"] += 1
        h["sum"] += value
        h["min"] = min(h["min"], value)
        h["max"] = max(h["max"], value)
        samples = self.hist_samples.setdefault(name, [])
        if len(samples) < HIST_SAMPLE_CAP:
            samples.append(value)
        self._emit(
            {
                **attrs,
                "kind": "hist",
                "name": name,
                "ts": self.clock(),
                "lane": lane,
                "value": value,
            }
        )

    def quantile(self, name: str, q: float) -> float:
        """Quantile of histogram ``name``'s retained samples (0 if empty).

        Exact (linear interpolation over every observed value) until the
        histogram passes :data:`HIST_SAMPLE_CAP` observations, after which
        it is the quantile of the first cap-many values.
        """
        return quantile(self.hist_samples.get(name, []), q)

    def hist_quantiles(
        self, name: str, qs: Tuple[float, ...] = (0.5, 0.99)
    ) -> Dict[float, float]:
        """Several quantiles of histogram ``name`` at once (p50/p99 default)."""
        return {q: self.quantile(name, q) for q in qs}

    def event(
        self, name: str, lane: Optional[int] = None, **attrs: Any
    ) -> None:
        """Emit a point-in-time (instant) event."""
        self._emit(
            {
                **attrs,
                "kind": "instant",
                "name": name,
                "ts": self.clock(),
                "lane": lane,
            }
        )

    # -- spans -------------------------------------------------------------

    @contextmanager
    def span(
        self, name: str, lane: Optional[int] = None, **attrs: Any
    ) -> Iterator[Dict[str, Any]]:
        """Record a nested duration span around the ``with`` body.

        Yields a mutable attrs dict — entries added inside the body ride on
        the ``span_end`` event (e.g. ``sp["executed"] = k`` after a fused
        dispatch returns how many iterations actually ran).
        """
        t0 = self.clock()
        depth = self._span_depth
        self._span_depth = depth + 1
        self._emit(
            {
                **attrs,
                "kind": "span_begin",
                "name": name,
                "ts": t0,
                "lane": lane,
                "depth": depth,
            }
        )
        merged: Dict[str, Any] = dict(attrs)
        try:
            yield merged
        finally:
            t1 = self.clock()
            self._span_depth = depth
            tot = self.span_totals.get(name)
            if tot is None:
                tot = self.span_totals[name] = {"count": 0, "total_s": 0.0}
            tot["count"] += 1
            tot["total_s"] += t1 - t0
            self._emit(
                {
                    **merged,
                    "kind": "span_end",
                    "name": name,
                    "ts": t1,
                    "lane": lane,
                    "depth": depth,
                    "dur": t1 - t0,
                }
            )

    # -- flows -------------------------------------------------------------

    def flow(
        self,
        name: str,
        src_lane: Optional[int],
        dst_lane: Optional[int],
        **attrs: Any,
    ) -> int:
        """Record a cross-lane flow (slot migration, reroute) as a
        begin/end pair sharing a fresh flow id; returns that id.

        Trace export turns each pair into a Perfetto flow arrow from the
        source lane to the destination lane.
        """
        self._flow_id += 1
        fid = self._flow_id
        ts = self.clock()
        self._emit(
            {
                **attrs,
                "kind": "flow_begin",
                "name": name,
                "ts": ts,
                "lane": src_lane,
                "id": fid,
            }
        )
        self._emit(
            {
                **attrs,
                "kind": "flow_end",
                "name": name,
                "ts": ts,
                "lane": dst_lane,
                "id": fid,
            }
        )
        return fid


class _NullSpan:
    """Reusable no-op context manager; swallows attr writes."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def __setitem__(self, key: str, value: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """No-op recorder: telemetry off.

    Call sites keep a module- or instance-level reference to :data:`NULL`
    and call it unconditionally; every method returns immediately.  Guard
    anything that *computes* (reshapes, sums, string formatting) with
    ``if rec.enabled:`` so disabled telemetry does no work at all.
    """

    enabled = False
    sinks: Tuple[Any, ...] = ()
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Dict[str, float]] = {}
    hist_samples: Dict[str, List[float]] = {}
    span_totals: Dict[str, Dict[str, float]] = {}

    def add_sink(self, sink: Any) -> None:  # pragma: no cover - misuse guard
        raise RuntimeError(
            "cannot attach a sink to the NULL recorder; build a Recorder()"
        )

    def count(self, name: str, n: float = 1, lane: Optional[int] = None, **attrs: Any) -> None:
        return None

    def gauge(self, name: str, value: float, lane: Optional[int] = None, **attrs: Any) -> None:
        return None

    def observe(self, name: str, value: float, lane: Optional[int] = None, **attrs: Any) -> None:
        return None

    def quantile(self, name: str, q: float) -> float:
        return 0.0

    def hist_quantiles(
        self, name: str, qs: Tuple[float, ...] = (0.5, 0.99)
    ) -> Dict[float, float]:
        return {q: 0.0 for q in qs}

    def event(self, name: str, lane: Optional[int] = None, **attrs: Any) -> None:
        return None

    def span(self, name: str, lane: Optional[int] = None, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def flow(
        self,
        name: str,
        src_lane: Optional[int],
        dst_lane: Optional[int],
        **attrs: Any,
    ) -> int:
        return 0

    def flush(self) -> None:
        return None

    def close(self) -> None:
        return None


#: Module-level disabled recorder — the default everywhere.
NULL = NullRecorder()
