"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-32b-smoke",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        head_dim=16,
        d_ff=512,
        vocab_size=512,
        dtype="float32",
    )
