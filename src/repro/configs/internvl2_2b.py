"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2-1.8B backbone
[arXiv:2404.16821].  The ViT is a STUB: `input_specs` feeds precomputed
patch embeddings (B, n_frontend_tokens, d_model)."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    n_frontend_tokens=256,  # one 448x448 tile -> 256 visual tokens
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="internvl2-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=384,
        vocab_size=512,
        n_frontend_tokens=16,
        dtype="float32",
    )
