"""hubert-xlarge [audio] — encoder-only transformer backbone
[arXiv:2106.07447].  The conv feature extractor is a STUB: `input_specs`
feeds precomputed frame embeddings (B, S, d_model); the head predicts the
504-unit cluster vocabulary per frame."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,       # encoder-only: bidirectional, no decode step
    frontend="audio",
    n_frontend_tokens=-1,  # the whole sequence comes from the frontend
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="hubert-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=384,
        vocab_size=64,
        dtype="float32",
    )
