"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family configuration
for CPU smoke tests (small widths/depths, few experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "mamba2-370m",
    "deepseek-7b",
    "minitron-4b",
    "mistral-nemo-12b",
    "qwen3-32b",
    "jamba-v0.1-52b",
    "internvl2-2b",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-236b",
    "hubert-xlarge",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()
