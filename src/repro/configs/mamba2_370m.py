"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=0,             # no separate MLP: the mamba mixer is the whole block
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mamba2-smoke",
        n_layers=4,
        d_model=128,
        vocab_size=512,
        ssm_state=32,
        ssm_headdim=32,
        ssm_chunk=32,
        dtype="float32",
    )
