"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B
family; per-expert d_ff 1536]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,          # per-expert FFN width
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    moe_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    moe_layer_period=1,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=96,
        vocab_size=512,
        moe_experts=8,
        moe_top_k=2,
        capacity_factor=8.0,  # no token drops: smoke tests check causal equivalence
        moe_d_ff=96,
        dtype="float32",
    )
