"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,       # nemo uses head_dim 128 (not d_model/n_heads = 160)
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mistral-nemo-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=384,
        vocab_size=512,
        dtype="float32",
    )
