"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-7b-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=352,
        vocab_size=512,
        dtype="float32",
    )
