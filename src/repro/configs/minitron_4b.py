"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="minitron-4b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=288,
        vocab_size=512,
        dtype="float32",
    )
