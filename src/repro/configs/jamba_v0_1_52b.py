"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    # attention on layer 4 of each 8-layer block (1:7 attn:mamba)
    attn_layer_period=8,
    attn_layer_offset=4,
    # MoE every other layer, 16 experts top-2
    moe_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    # mamba mixer dims
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="jamba-smoke",
        n_layers=8,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        moe_experts=4,
        moe_top_k=2,
        capacity_factor=8.0,  # no token drops: smoke tests check causal equivalence
        moe_d_ff=256,
        ssm_state=16,
        ssm_headdim=32,
        ssm_chunk=32,
        dtype="float32",
    )
