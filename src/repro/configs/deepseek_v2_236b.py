"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,     # MLA: logical heads (cache is latent, shared)
    d_ff=12288,         # dense FFN width (first layer)
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe_experts=160,
    moe_top_k=6,
    moe_d_ff=1536,
    moe_shared_experts=2,
    moe_layer_period=1,
    first_k_dense=1,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-v2-smoke",
        n_layers=3,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        kv_lora_rank=32,
        q_lora_rank=48,
        qk_rope_head_dim=16,
        qk_nope_head_dim=32,
        v_head_dim=32,
        moe_experts=8,
        moe_top_k=2,
        capacity_factor=8.0,  # no token drops: smoke tests check causal equivalence
        moe_d_ff=64,
        moe_shared_experts=1,
        first_k_dense=1,
        dtype="float32",
    )
