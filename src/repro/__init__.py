"""repro: adaptive multidimensional quadrature + multi-pod LM substrate."""
__version__ = "0.1.0"
