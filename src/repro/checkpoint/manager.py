"""Fault-tolerant checkpointing: atomic, async, mesh-reshardable.

Fault-tolerance contract (DESIGN.md §6):

- **atomic**: writes go to ``step_XXXXXXXX.tmp/`` and are renamed only after
  the manifest (tree structure + shapes + dtypes + CRC32 per leaf) has been
  fsync'd — a crash mid-write can never corrupt the latest checkpoint;
- **async**: `save()` snapshots device arrays to host and hands the file I/O
  to a background thread, returning control to the training loop immediately
  (`wait()` joins before the next save or at exit);
- **elastic restarts**: `restore()` takes the *current* mesh/sharding spec;
  arrays are loaded as full logical values and re-placed with the new
  sharding, so a job restarted on a different pod count (e.g. after losing
  a pod) resumes from the same step with a different layout.
"""

from __future__ import annotations

import json
import os
import re
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [
        (
            "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path),
            leaf,
        )
        for path, leaf in leaves
    ]
    return named, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        named, _ = _flatten(tree)
        # snapshot to host now (cheap on CPU, device->host copy on TPU) so the
        # training loop can keep mutating device buffers
        host = [(name, np.asarray(leaf)) for name, leaf in named]
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host)
            )
            self._thread.start()

    def _write_guarded(self, step: int, host) -> None:
        # A bare thread target swallows exceptions: a failed async write
        # (disk full, the FileExistsError re-save guard, a permissions
        # error) would otherwise leave the caller believing the checkpoint
        # landed.  Capture and surface on the next wait()/save().
        try:
            self._write(step, host)
        except BaseException as exc:  # noqa: BLE001 - resurfaced in wait()
            self._error = exc

    def _write(self, step: int, host) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        arrays = {}
        for i, (name, arr) in enumerate(host):
            key = f"a{i}"
            arrays[key] = arr
            manifest[name] = {
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            raise FileExistsError(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            path = os.path.join(self.dir, f"step_{s:08d}")
            for root, dirs, files in os.walk(path, topdown=False):
                for fn in files:
                    os.unlink(os.path.join(root, fn))
                for dn in dirs:
                    os.rmdir(os.path.join(root, dn))
            os.rmdir(path)

    def wait(self) -> None:
        """Join a pending async save; re-raise its exception if it failed."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            exc, self._error = self._error, None
            raise exc

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching pytree of
        NamedShardings for elastic re-placement on the current mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        data = np.load(os.path.join(path, "arrays.npz"))

        named, treedef = _flatten(like)
        out_leaves = []
        for name, leaf in named:
            if name not in manifest:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            meta = manifest[name]
            arr = data[meta["key"]]
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                raise IOError(f"CRC mismatch for {name!r} (corrupt checkpoint)")
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{name}: shape {arr.shape} != {want_shape}")
            out_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, step
