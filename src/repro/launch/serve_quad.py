"""Batch quadrature service launcher (continuous batching over a request fleet).

Serve 64 random Genz-Gaussian problems through 16 batch slots:
  PYTHONPATH=src python -m repro.launch.serve_quad --family genz_gaussian \
      --d 3 --n-requests 64 --batch-slots 16
Shard the fleet across 4 devices with cyclic problem rebalancing:
  PYTHONPATH=src python -m repro.launch.serve_quad --d 3 --n-requests 64 \
      --batch-slots 16 --devices 4 --rebalance ring
Explicit problems (one family spec per --request, see integrands.from_spec):
  PYTHONPATH=src python -m repro.launch.serve_quad --d 2 \
      --request genz_gaussian:5,5:0.3,0.7 --request genz_gaussian:8,2:0.5,0.5
Graceful degradation + crash recovery (see DESIGN.md §6): re-route degraded
requests, snapshot every admission tick, resume after a crash:
  PYTHONPATH=src python -m repro.launch.serve_quad --d 3 --n-requests 64 \
      --graceful --checkpoint-dir /tmp/quad-ckpt
  PYTHONPATH=src python -m repro.launch.serve_quad --d 3 --n-requests 64 \
      --graceful --checkpoint-dir /tmp/quad-ckpt --resume
Observability (DESIGN.md §8): Chrome trace + metrics stream + summary:
  PYTHONPATH=src python -m repro.launch.serve_quad --d 3 --n-requests 64 \
      --devices 4 --trace /tmp/quad-trace.json --metrics /tmp/quad.jsonl \
      --telemetry-summary
Elastic resilience (DESIGN.md §6): kill device 2 at iteration 3, watch the
fleet evacuate its slots, shrink the mesh, and finish anyway:
  PYTHONPATH=src python -m repro.launch.serve_quad --d 3 --n-requests 64 \
      --devices 4 --chaos-fail-device 2:3 --strict
"""

import argparse
import time

from repro.telemetry.logutil import add_verbosity_flags, setup_logging


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--family", default="genz_gaussian")
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument(
        "--n-requests", type=int, default=32, help="random problems to sample"
    )
    ap.add_argument(
        "--request",
        action="append",
        default=[],
        metavar="SPEC",
        help="explicit family spec (repeatable; overrides --n-requests)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rel-tol", type=float, default=1e-6)
    ap.add_argument(
        "--rel-tols",
        default=None,
        metavar="TOL[,TOL...]",
        help="per-request tolerances, cycled over the fleet (e.g. "
        "'1e-2,1e-8' stripes easy/hard problems across slots — the "
        "load-imbalanced fleet that exercises ring rebalancing)",
    )
    ap.add_argument("--capacity", type=int, default=1 << 12)
    ap.add_argument("--batch-slots", type=int, default=16)
    ap.add_argument("--admit-every", type=int, default=1)
    ap.add_argument("--eval-window-min", type=int, default=256)
    ap.add_argument(
        "--advance-window",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="window the vmapped advance stage too (bit-identical)",
    )
    ap.add_argument(
        "--use-kernel",
        action="store_true",
        help="fused Pallas GM kernel (theta rides as a kernel operand)",
    )
    ap.add_argument(
        "--interpret",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="Pallas interpret mode (keep on for CPU; --no-interpret on TPU)",
    )
    ap.add_argument("--max-iters", type=int, default=300)
    ap.add_argument(
        "--backend",
        default="cubature",
        choices=["cubature", "vegas", "auto"],
        help="engine pool backing the fleet: deterministic cubature, the "
        "VEGAS Monte Carlo subsystem (high d), or auto (by dimension)",
    )
    ap.add_argument(
        "--mc-samples", type=int, default=8192, help="vegas samples per iteration"
    )
    ap.add_argument(
        "--mc-iters", type=int, default=100, help="vegas iteration cap"
    )
    ap.add_argument(
        "--mc-seed", type=int, default=0, help="vegas PRNG seed (deterministic)"
    )
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument(
        "--devices",
        type=int,
        default=1,
        help="mesh size the slot axis is sharded over (0 = all visible devices)",
    )
    ap.add_argument(
        "--rebalance",
        choices=("ring", "off"),
        default="ring",
        help="cyclic problem migration between ring partners when a device drains",
    )
    ap.add_argument(
        "--max-state-bytes",
        type=int,
        default=2 << 30,
        help="refuse fleets whose stacked region store exceeds this many bytes",
    )
    ap.add_argument(
        "--validate", action="store_true", help="print true error vs analytic exact"
    )
    ap.add_argument(
        "--graceful",
        action="store_true",
        help="serve through the graceful-degradation layer: capacity/"
        "nonfinite evictions are re-routed once to the VEGAS pool, "
        "tolerance-starved requests retried at a loosened tolerance "
        "(results carry attempt provenance)",
    )
    ap.add_argument(
        "--deadline-s",
        type=float,
        default=None,
        help="per-request wall-clock SLO in seconds (expired slots are "
        "evicted with a best-effort partial result, status 'deadline')",
    )
    ap.add_argument(
        "--max-evals",
        type=float,
        default=None,
        help="per-request integrand-evaluation SLO (deterministic analogue "
        "of --deadline-s)",
    )
    ap.add_argument(
        "--checkpoint-dir",
        default=None,
        help="directory for service snapshots (engine state + slot map)",
    )
    ap.add_argument(
        "--checkpoint-every",
        type=int,
        default=1,
        help="snapshot every N admission ticks (needs --checkpoint-dir)",
    )
    ap.add_argument(
        "--resume",
        action="store_true",
        help="restore the latest snapshot in --checkpoint-dir and replay: "
        "already-pulled requests are skipped, in-flight slots resume "
        "mid-refinement (bit-identical for slots the crash did not touch)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="write a Chrome trace-event file (load in chrome://tracing or "
        "ui.perfetto.dev): one lane per device, spans for compile/dispatch/"
        "admit/collect/checkpoint, flow arrows for migrations and reroutes",
    )
    ap.add_argument(
        "--metrics",
        default=None,
        metavar="PATH",
        help="stream telemetry events to PATH as JSON Lines",
    )
    ap.add_argument(
        "--telemetry-summary",
        action="store_true",
        help="print the end-of-run counter/span summary table",
    )
    ap.add_argument(
        "--chaos-fail-device",
        default=None,
        metavar="DEV:TICK[:RESTORE]",
        help="inject a permanent device loss: device index DEV fails at "
        "iteration TICK (optionally healing at iteration RESTORE, so the "
        "mesh regrows) — exercises watchdog / evacuation / shrink, see "
        "DESIGN.md §6",
    )
    ap.add_argument(
        "--max-dispatch-retries",
        type=int,
        default=2,
        help="transient dispatch faults retried (with backoff) before the "
        "faulting device is declared permanently lost",
    )
    ap.add_argument(
        "--dispatch-timeout-s",
        type=float,
        default=None,
        help="watchdog timeout per fused dispatch: a wedged device surfaces "
        "as a DispatchTimeout instead of hanging the serve loop",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit non-zero unless every result is finite AND converged; "
        "runs that completed only via device-loss evacuation or retry "
        "exit 0 but log a degraded-mode warning with per-request "
        "provenance",
    )
    add_verbosity_flags(ap)
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    log = setup_logging(quiet=args.quiet, verbose=args.verbose)

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import QuadratureConfig
    from repro.core.integrands import get_param, parse_spec
    from repro.service import QuadRequest, serve
    from repro.service.batch_engine import estimate_state_bytes

    family = get_param(args.family)
    cfg = QuadratureConfig(
        d=args.d,
        integrand=args.family,
        rel_tol=args.rel_tol,
        capacity=args.capacity,
        batch_slots=args.batch_slots,
        admit_every=args.admit_every,
        eval_window_min=args.eval_window_min,
        advance_window=args.advance_window,
        use_kernel=args.use_kernel,
        interpret=args.interpret,
        max_iters=args.max_iters,
        backend=args.backend,
        mc_samples=args.mc_samples,
        mc_max_iters=args.mc_iters,
        mc_seed=args.mc_seed,
        sync_every=args.sync_every,
        service_devices=args.devices,
        rebalance=args.rebalance,
    )
    vegas = cfg.resolved_backend() == "vegas"
    if vegas and args.devices not in (0, 1):
        raise SystemExit(
            "--backend vegas serves through a single-device vmapped pool "
            "(MC parallelism shards samples, not slots — see "
            "repro.mc.multi_device); drop --devices"
        )

    # Fail fast on fleets the region store cannot accommodate: the stacked
    # store allocates batch_slots x capacity regions up front, so an oversized
    # --batch-slots would otherwise die deep inside XLA allocation (or swap
    # the host to death) instead of telling the operator what to change.
    # (The vegas pool's state is a few KB of grid edges per slot — no check.)
    need = 0 if vegas else estimate_state_bytes(cfg, family)
    if need > args.max_state_bytes:
        raise SystemExit(
            f"--batch-slots {args.batch_slots} x --capacity {args.capacity} "
            f"needs ~{need / 2**30:.2f} GiB of region-store state, over the "
            f"{args.max_state_bytes / 2**30:.2f} GiB limit; lower "
            "--batch-slots or --capacity (or raise --max-state-bytes if the "
            "hardware really has the memory)"
        )
    n_devices = (
        1
        if vegas
        else len(jax.devices()) if args.devices == 0 else args.devices
    )
    if n_devices > len(jax.devices()):
        raise SystemExit(
            f"--devices {args.devices} but only {len(jax.devices())} devices "
            "are visible (set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "to emulate a mesh on CPU)"
        )
    if args.batch_slots % n_devices:
        raise SystemExit(
            f"--batch-slots {args.batch_slots} must be a multiple of "
            f"--devices ({n_devices}): each device owns a contiguous block "
            "of batch_slots / devices slots"
        )

    if args.request:
        thetas = []
        for spec in args.request:
            req_family, theta = parse_spec(spec)
            if req_family.name != family.name:
                raise SystemExit(
                    f"--request {spec!r} names family {req_family.name!r}, "
                    f"but --family is {args.family!r}"
                )
            thetas.append(theta)
    else:
        rng = np.random.default_rng(args.seed)
        thetas = [family.sample_theta(args.d, rng) for _ in range(args.n_requests)]

    rel_tols = None
    if args.rel_tols:
        rel_tols = [float(t) for t in args.rel_tols.split(",")]
    requests = [
        QuadRequest(
            req_id=i,
            theta=t,
            rel_tol=None if rel_tols is None else rel_tols[i % len(rel_tols)],
            deadline_s=args.deadline_s,
            max_evals=args.max_evals,
        )
        for i, t in enumerate(thetas)
    ]
    log.info(
        "serving %d x %s (d=%d) through %d slots on %d device(s) "
        "(rebalance=%s), rel_tol=%s",
        len(requests),
        family.name,
        args.d,
        cfg.batch_slots,
        n_devices,
        cfg.rebalance,
        args.rel_tols if rel_tols else f"{cfg.rel_tol:g}",
    )
    serve_kwargs = {
        "max_dispatch_retries": args.max_dispatch_retries,
        "dispatch_timeout_s": args.dispatch_timeout_s,
    }
    if args.checkpoint_dir:
        from repro.service import ServiceCheckpointer

        serve_kwargs["checkpointer"] = ServiceCheckpointer(args.checkpoint_dir)
        serve_kwargs["checkpoint_every"] = args.checkpoint_every
    if args.chaos_fail_device:
        from repro.service.faults import DeviceDown

        parts = args.chaos_fail_device.split(":")
        if len(parts) not in (2, 3):
            raise SystemExit(
                f"--chaos-fail-device {args.chaos_fail_device!r}: expected "
                "DEV:TICK or DEV:TICK:RESTORE"
            )
        dev, tick = int(parts[0]), int(parts[1])
        restore = int(parts[2]) if len(parts) == 3 else None
        if not 0 <= dev < n_devices:
            raise SystemExit(
                f"--chaos-fail-device device {dev} out of range for "
                f"{n_devices} device(s)"
            )
        if n_devices < 2:
            raise SystemExit(
                "--chaos-fail-device needs --devices >= 2: a single-device "
                "fleet has no surviving sub-mesh to evacuate onto"
            )
        serve_kwargs["fault_injector"] = DeviceDown(
            device=dev, at_tick=tick, restore_at_tick=restore
        )
        log.info(
            "chaos: device %d fails at iteration %d%s",
            dev,
            tick,
            "" if restore is None else f", heals at iteration {restore}",
        )

    from repro.telemetry import JsonlSink, MemorySink, Recorder, summary_table
    from repro.telemetry.trace import write_chrome_trace

    recorder = None
    trace_sink = None
    if args.trace or args.metrics or args.telemetry_summary:
        sinks = []
        if args.trace:
            trace_sink = MemorySink()
            sinks.append(trace_sink)
        if args.metrics:
            sinks.append(JsonlSink(args.metrics))
        recorder = Recorder(sinks=tuple(sinks))
        serve_kwargs["recorder"] = recorder

    t0 = time.perf_counter()
    results = []
    for res in serve(
        cfg,
        requests,
        family,
        graceful=args.graceful,
        resume=args.resume,
        **serve_kwargs,
    ):
        results.append(res)
        line = res.summary()
        if args.validate:
            exact = family.exact(args.d, thetas[res.req_id])
            rel = abs(res.integral - exact) / max(abs(exact), 1e-300)
            line += f" true_rel_err={rel:.2e}"
        log.info("[%d/%d] %s", len(results), len(requests), line)
    dt = time.perf_counter() - t0
    log.info(
        "done: %d problems in %.2fs (%.1f problems/sec)",
        len(requests),
        dt,
        len(requests) / dt,
    )
    if recorder is not None:
        recorder.close()
        if args.trace:
            write_chrome_trace(args.trace, trace_sink.events)
            log.info("wrote Chrome trace: %s (load in ui.perfetto.dev)", args.trace)
        if args.metrics:
            log.info("wrote metrics JSONL: %s", args.metrics)
        if args.telemetry_summary:
            log.info("telemetry summary:\n%s", summary_table(recorder))

    if args.strict:
        import math
        import sys

        hints = {
            "max_iters": "raise --max-iters (or --mc-iters for vegas), or "
            "loosen --rel-tol",
            "capacity": "raise --capacity or loosen --rel-tol",
            "nonfinite": "the integrand produced NaN/Inf on this domain; "
            "check the integrand/theta for poles or overflow",
            "deadline": "raise --deadline-s / --max-evals or loosen the "
            "tolerance",
            "no_active": "the region population collapsed; loosen --rel-tol",
        }
        problems = []
        for res in sorted(results, key=lambda r: r.req_id):
            if not (math.isfinite(res.integral) and math.isfinite(res.error)):
                problems.append(
                    f"req {res.req_id}: non-finite result "
                    f"(integral={res.integral!r}, error={res.error!r})"
                )
            elif res.status != "converged":
                hint = hints.get(res.status, "see the status taxonomy in DESIGN.md")
                problems.append(
                    f"req {res.req_id}: status={res.status!r} (hint: {hint})"
                )
        # Converged-but-degraded requests (device-loss evacuations, watchdog
        # or fallback retries) pass strict mode — the answer is correct, the
        # road there was not — but the degradation is loud, with provenance,
        # so a scripted caller can still grep for it.
        degraded = [
            r
            for r in sorted(results, key=lambda r: r.req_id)
            if (r.evacuated or r.attempts > 1)
            and not (
                not (math.isfinite(r.integral) and math.isfinite(r.error))
                or r.status != "converged"
            )
        ]
        for r in degraded:
            log.warning(
                "STRICT-DEGRADED: req %d converged after recovery "
                "(attempts=%d, retried_from=%s, evacuated=%s)",
                r.req_id,
                r.attempts,
                r.retried_from,
                r.evacuated,
            )
        if problems:
            # via logging, not print: serve_quad is print-free by contract
            # (tests/test_no_print.py) — errors ride the same stream -q
            # controls, and the non-zero exit is what scripted callers gate on
            log.error("STRICT: %s", "; ".join(problems))
            sys.exit(1)


if __name__ == "__main__":
    main()
