"""Production serving launcher (batched prefill + decode).

CPU container: runs reduced smoke configs; the dry-run proves the full-mesh
serve paths (prefill_32k / decode_32k / long_500k cells).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 [--chunked-prefill 16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import (
    cache_init,
    model_decode,
    model_init,
    model_prefill,
    model_prefill_chunked,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--chunked-prefill", type=int, default=0, help="chunk size")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")

    params = model_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.new_tokens
    caches = cache_init(cfg, args.batch, max_len)

    t0 = time.time()
    if args.chunked_prefill:
        logits, caches = jax.jit(
            lambda p, t, c: model_prefill_chunked(
                cfg, p, t, c, args.chunked_prefill
            )
        )(params, prompt, caches)
    else:
        logits, caches = jax.jit(lambda p, t, c: model_prefill(cfg, p, t, c))(
            params, prompt, caches
        )
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, t, c, pos: model_decode(cfg, p, t, c, pos))
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [token]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        token, caches = decode(
            params, token, caches, jnp.asarray(args.prompt_len + i)
        )
        out.append(token)
    t_decode = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"{cfg.name}: prompt {args.prompt_len}, generated {args.new_tokens}")
    print(f"sample[0]: {toks[0]}")
    print(
        f"prefill {t_prefill:.2f}s; decode {t_decode:.2f}s "
        f"({args.batch * (args.new_tokens-1) / max(t_decode, 1e-9):,.1f} tok/s incl. compile)"
    )


if __name__ == "__main__":
    main()
