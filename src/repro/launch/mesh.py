"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    pure data parallelism whose collectives cross the DCN/ICI pod boundary.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_quadrature_mesh(n_devices: int | None = None):
    """1-D device ring for the distributed quadrature engine."""
    devices = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    return jax.make_mesh((len(devices),), ("dev",), devices=devices)
