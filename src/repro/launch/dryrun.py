import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import anywhere in the process:
jax locks the device count on first backend initialisation.  smoke tests and
benchmarks never import this module, so they see the real single CPU device.

For each cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16)),
  2. derives parameter/optimizer/cache/batch shardings from the rules engine,
  3. ``jit(step).lower(abstract inputs).compile()`` — proving the sharding
     config is coherent (no shape mismatch, no unsupported collective, fits
     memory),
  4. records memory_analysis(), cost_analysis() and the per-device collective
     byte counts parsed from the partitioned HLO (§Roofline input).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/dryrun] [--microbatches N]
"""

import argparse
import dataclasses
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import ShardingCtx, mesh_rules, param_spec_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_status, input_specs
from repro.models.config import ModelConfig
from repro.models.model import (
    cache_init,
    model_decode,
    model_forward,
    model_init,
    model_prefill,
    model_prefill_chunked,
)
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train import TrainConfig, make_train_step

# --------------------------------------------------------------------------
# sharding spec builders
# --------------------------------------------------------------------------

_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "embeds": ("batch", "seq", "embed"),
    "token": ("batch",),
}


def batch_spec_tree(batch_abstract, ctx: ShardingCtx):
    return {
        k: NamedSharding(ctx.mesh, ctx.spec(v.shape, _BATCH_AXES[k]))
        for k, v in batch_abstract.items()
    }


def cache_logical_axes(path: str, ndim: int, rules: dict):
    """Logical names for a cache leaf (leading dim may be the period stack)."""
    if path.endswith("/k") or path.endswith("/v"):
        names = ("batch", "kv_seq", "kv_heads", "head_dim")
    elif path.endswith("c_kv") or path.endswith("k_rope"):
        names = ("batch", "kv_seq", "mla_rank")
    elif path.endswith("ssm"):
        names = ("batch", "ssm_heads", None, None)
    elif path.endswith("conv"):
        names = ("batch", None, "ssm_inner")
    else:
        names = tuple(None for _ in range(ndim))
    if len(names) == ndim - 1:
        names = (None,) + names
    assert len(names) == ndim, (path, names, ndim)
    return names


def cache_spec_tree(cache_abstract, ctx: ShardingCtx, rules: dict):
    def leaf(path, x):
        pathstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        names = cache_logical_axes(pathstr, x.ndim, rules)
        return NamedSharding(ctx.mesh, ctx.spec(x.shape, names))

    return jax.tree_util.tree_map_with_path(leaf, cache_abstract)


# --------------------------------------------------------------------------
# step builders (shared with benchmarks.roofline)
# --------------------------------------------------------------------------


def build_cell(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    *,
    microbatches: int = 8,
    remat: str = "full",
    zero1: bool = False,
    rules: dict | None = None,
):
    """Returns (fn, example_args, in_shardings, donate) for jit lowering."""
    shape = SHAPES[shape_name]
    rules = dict(rules or {})
    model_ways = mesh.shape.get("model", 1)
    heads_shardable = (not cfg.use_mla) and cfg.n_kv_heads % model_ways == 0
    if shape.kind == "decode" and cfg.use_mla:
        # hillclimb C (confirmed): shard the MLA latent cache on its RANK dim
        # — the per-token insert stays device-local (seq-sharding forces SPMD
        # to rematerialize the whole cache per step) and the score
        # contraction pays only a small per-block psum.  1.41 -> 0.37 GiB
        # collectives, 8.0 -> 7.0 GiB temps on deepseek-v2 decode_32k.
        rules.setdefault("mla_rank", "model")
        rules.setdefault("kv_seq", None)
    elif shape.kind == "decode" and not heads_shardable:
        # sequence-parallel KV/latent cache: GQA kv-head counts (4/8) and the
        # MLA latent (no head dim at all) cannot shard over the 16-way model
        # axis; replicating a 32k-context cache costs 18-25 GiB/chip, so the
        # cache shards its SEQUENCE dim instead (blockwise attention streams
        # blocks, so each step touches one shard's worth per block)
        rules.setdefault("kv_seq", "model")
        rules.setdefault("kv_heads", None)
    if shape.kind != "train":
        # embedding-table rows stay unsharded when serving: SPMD lowers a
        # gather from a row-sharded table via full replication ("involuntary
        # full rematerialization" warnings + tens of GiB of temps)
        rules.setdefault("vocab_rows", None)
    ctx = ShardingCtx(mesh, rules)

    abstract_params = jax.eval_shape(partial(model_init, cfg), jax.random.PRNGKey(0))
    if shape.kind != "train":
        # serving deploys bf16 checkpoints (fp32 masters are a training
        # concern); >=2-D leaves are the weight matrices
        abstract_params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.ndim >= 2 else s.dtype
            ),
            abstract_params,
        )
    p_spec = param_spec_tree(abstract_params, mesh, rules)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)
    batch = input_specs(cfg, shape)
    b_shard = batch_spec_tree(batch, ctx)

    if shape.kind == "train":
        tcfg = TrainConfig(
            remat=remat,
            microbatches=microbatches,
            opt=OptimizerConfig(zero1=zero1),
        )
        abstract_opt = jax.eval_shape(
            partial(init_opt_state, tcfg.opt), abstract_params
        )
        o_spec = param_spec_tree(
            {"mu": abstract_opt["mu"], "nu": abstract_opt["nu"]}, mesh, rules
        )
        o_shard = {
            "mu": jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec["mu"]),
            "nu": jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec["nu"]),
            "count": NamedSharding(mesh, P()),
        }
        step = make_train_step(cfg, tcfg, param_shardings=p_shard)

        def fn(params, opt_state, batch):
            with mesh_rules(mesh, rules):
                return step(params, opt_state, batch)

        args = (abstract_params, abstract_opt, batch)
        shardings = (p_shard, o_shard, b_shard)
        return fn, args, shardings, (0, 1)

    if shape.kind == "prefill":
        if not cfg.has_decode:  # encoder-only: plain forward
            def fn(params, batch):
                with mesh_rules(mesh, rules):
                    logits, _ = model_forward(cfg, params, **batch)
                    return logits

            return fn, (abstract_params, batch), (p_shard, b_shard), ()

        abstract_cache = jax.eval_shape(
            partial(cache_init, cfg, shape.global_batch, shape.seq_len)
        )
        c_shard = cache_spec_tree(abstract_cache, ctx, rules)
        # long prompts run the chunked (Sarathi-style) prefill so the MoE
        # dispatch / attention working set is bounded by the chunk
        chunk = 4096 if shape.seq_len >= 8192 else None

        def fn(params, batch, caches):
            with mesh_rules(mesh, rules):
                if chunk is not None:
                    return model_prefill_chunked(
                        cfg, params, batch.get("tokens"), caches, chunk,
                        embeds=batch.get("embeds"),
                    )
                return model_prefill(
                    cfg,
                    params,
                    batch.get("tokens"),
                    caches,
                    embeds=batch.get("embeds"),
                )

        args = (abstract_params, batch, abstract_cache)
        return fn, args, (p_shard, b_shard, c_shard), (2,)

    # decode: one token against a full-length cache
    abstract_cache = jax.eval_shape(
        partial(cache_init, cfg, shape.global_batch, shape.seq_len)
    )
    c_shard = cache_spec_tree(abstract_cache, ctx, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, token, caches, pos):
        with mesh_rules(mesh, rules):
            return model_decode(cfg, params, token, caches, pos)

    args = (abstract_params, batch["token"], abstract_cache, pos)
    tok_shard = b_shard["token"]
    return fn, args, (p_shard, tok_shard, c_shard, NamedSharding(mesh, P())), (2,)


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device payload bytes by collective kind, from partitioned HLO.

    Shapes in the post-SPMD module are PER-DEVICE, so summed output bytes
    approximate the per-device link payload (all-reduce is counted twice:
    reduce-scatter + all-gather phases of a ring implementation).
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(sig)
        if kind == "all-reduce":
            nbytes *= 2  # ring AR = RS + AG passes
        out[kind] += nbytes
        out["count"] += 1
    return out


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------


# Per-arch gradient-accumulation defaults (train_4k): chosen by the memory/
# collective sweep in EXPERIMENTS.md §Perf — more microbatches shrink saved
# activations but re-gather FSDP weights per microbatch, so the sweet spot
# moves with model size.
MICROBATCH_DEFAULTS = {
    "qwen3-32b": 16,
    "jamba-v0.1-52b": 16,
    "qwen3-moe-235b-a22b": 16,
    "deepseek-v2-236b": 16,
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, **kw) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_status(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skip" if skip else "pending",
    }
    if skip:
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, shardings, donate = build_cell(cfg, shape_name, mesh, **kw)
        lowered = jax.jit(
            fn, in_shardings=shardings, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", -1)) if cost else -1,
            bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            collectives=coll,
        )
        if mem is not None:
            for field in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                val = getattr(mem, field, None)
                if val is not None:
                    rec[field] = int(val)
        return rec
    except Exception as e:  # noqa: BLE001 — a failed cell is a reportable bug
        rec.update(status="fail", error=f"{type(e).__name__}: {e}"[:2000])
        return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--zero1", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                mb = (
                    MICROBATCH_DEFAULTS.get(arch, args.microbatches)
                    if args.microbatches == 8
                    else args.microbatches
                )
                rec = run_cell(
                    arch,
                    shape,
                    multi,
                    microbatches=mb,
                    remat=args.remat,
                    zero1=args.zero1,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(
                    f"[{rec['status']:4}] {tag} "
                    + (
                        f"flops={rec.get('flops', 0):.3g} "
                        f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                        f"coll={sum(v for k, v in rec.get('collectives', {}).items() if k != 'count')/2**20:.1f}MiB"
                        if rec["status"] == "ok"
                        else rec.get("reason", rec.get("error", ""))[:200]
                    ),
                    flush=True,
                )


if __name__ == "__main__":
    main()
