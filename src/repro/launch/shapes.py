"""Assigned input-shape grid + abstract input specs (no allocation).

Every (arch x shape) cell resolves to a step kind:
  train_4k    -> train_step   (fwd+bwd+optimizer)
  prefill_32k -> serve prefill (encoder forward for encoder-only archs)
  decode_32k  -> serve decode  (one token against a full KV/state cache)
  long_500k   -> serve decode at 524288 context (sub-quadratic archs only)

Skip rules (DESIGN.md §5): full-attention archs skip long_500k;
encoder-only archs (hubert) skip both decode shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.kind == "decode" and not cfg.has_decode:
        return "skip(encoder-only: no decode step)"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip(full quadratic attention: 500k decode out of family scope)"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct

    if cfg.family == "audio":
        # frame embeddings from the (stubbed) conv frontend
        if shape.kind == "train":
            return {
                "embeds": sd((b, s, cfg.d_model), f32),
                "labels": sd((b, s), i32),
            }
        if shape.kind == "prefill":
            return {"embeds": sd((b, s, cfg.d_model), f32)}
        raise ValueError("encoder-only arch has no decode inputs")

    if cfg.family == "vlm":
        nf = cfg.n_frontend_tokens
        if shape.kind == "train":
            return {
                "tokens": sd((b, s - nf), i32),
                "embeds": sd((b, nf, cfg.d_model), f32),
                "labels": sd((b, s), i32),
                "loss_mask": sd((b, s), f32),
            }
        if shape.kind == "prefill":
            return {
                "tokens": sd((b, s - nf), i32),
                "embeds": sd((b, nf, cfg.d_model), f32),
            }
        return {"token": sd((b,), i32)}

    if shape.kind == "train":
        return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
    if shape.kind == "prefill":
        return {"tokens": sd((b, s), i32)}
    return {"token": sd((b,), i32)}
