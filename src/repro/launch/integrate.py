"""Quadrature launcher (the paper's solver as a CLI).

Single device:
  PYTHONPATH=src python -m repro.launch.integrate --integrand f4 --d 5 --rel-tol 1e-7
Distributed (one process, N local devices — same code on a real mesh):
  PYTHONPATH=src python -m repro.launch.integrate --devices 8 --integrand f6 --d 5
"""

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--integrand", default="f4")
    ap.add_argument("--d", type=int, default=5)
    ap.add_argument("--rel-tol", type=float, default=1e-7)
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--classifier", default="robust", choices=["robust", "aggressive"])
    ap.add_argument("--rule", default="genz_malik", choices=["genz_malik", "gauss_kronrod"])
    ap.add_argument("--use-kernel", action="store_true", help="Pallas GM kernel")
    ap.add_argument(
        "--interpret",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="Pallas interpret mode (keep on for CPU; --no-interpret on TPU)",
    )
    ap.add_argument(
        "--block-regions", type=int, default=0, help="kernel lanes per block (0 = default)"
    )
    ap.add_argument(
        "--eval-window",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="evaluate only the leading active window of the region store",
    )
    ap.add_argument(
        "--eval-window-min", type=int, default=256, help="smallest window ladder rung"
    )
    ap.add_argument(
        "--advance-window",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="window the advance stage (classify + split/compact + global "
        "reductions) as well — bit-identical, scales the whole iteration "
        "with the live population",
    )
    ap.add_argument("--max-iters", type=int, default=600)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--message-cap", type=int, default=512)
    ap.add_argument(
        "--redistribution",
        default="ring",
        choices=["ring", "off"],
        help="distributed load redistribution policy",
    )
    ap.add_argument(
        "--sync-every",
        type=int,
        default=4,
        help="iterations fused per dispatch in the distributed driver",
    )
    ap.add_argument("--device-loop", action="store_true", help="lax.while_loop driver")
    args = ap.parse_args()

    if args.devices > 1 and os.environ.get("_REPRO_INT_WORKER") != "1":
        env = dict(os.environ)
        env["_REPRO_INT_WORKER"] = "1"
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + env.get("XLA_FLAGS", "")
        )
        sys.exit(os.spawnvpe(os.P_WAIT, sys.executable, [sys.executable] + sys.argv, env))

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core import QuadratureConfig, integrate, integrate_device
    from repro.core.distributed import integrate_distributed
    from repro.core.integrands import REGISTRY, get

    cfg = QuadratureConfig(
        d=args.d,
        integrand=args.integrand,
        rel_tol=args.rel_tol,
        capacity=args.capacity,
        classifier=args.classifier,
        rule=args.rule,
        use_kernel=args.use_kernel,
        interpret=args.interpret,
        block_regions=args.block_regions,
        eval_window=args.eval_window,
        eval_window_min=args.eval_window_min,
        advance_window=args.advance_window,
        max_iters=args.max_iters,
        message_cap=args.message_cap,
        redistribution=args.redistribution,
        sync_every=args.sync_every,
    )
    if args.devices > 1:
        res = integrate_distributed(cfg)
        print(res.summary())
        print(f"devices={res.n_devices} mean_imbalance={res.mean_imbalance():.3f}")
    elif args.device_loop:
        res = integrate_device(cfg)
        print(res.summary())
    else:
        res = integrate(cfg)
        print(res.summary())
    if args.integrand in REGISTRY or ":" in args.integrand:
        # fixed registry entries and family specs (e.g. genz_gaussian:5,5:.3,.7)
        exact = get(args.integrand).exact(args.d)
        rel = abs(res.integral - exact) / max(abs(exact), 1e-300)
        print(f"exact={exact:.15e} true_rel_err={rel:.3e}")


if __name__ == "__main__":
    main()
