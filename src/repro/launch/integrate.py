"""Quadrature launcher (the paper's solver as a CLI).

Single device:
  PYTHONPATH=src python -m repro.launch.integrate --integrand f4 --d 5 --rel-tol 1e-7
Distributed (one process, N local devices — same code on a real mesh):
  PYTHONPATH=src python -m repro.launch.integrate --devices 8 --integrand f6 --d 5
High dimension via the VEGAS Monte Carlo backend (see DESIGN.md §7); a bare
family name samples a random theta from --theta-seed:
  PYTHONPATH=src python -m repro.launch.integrate --backend vegas --d 15 \
      --integrand genz_gaussian --rel-tol 1e-3
"""

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--integrand", default="f4")
    ap.add_argument("--d", type=int, default=5)
    ap.add_argument("--rel-tol", type=float, default=1e-7)
    ap.add_argument("--capacity", type=int, default=1 << 15)
    ap.add_argument("--classifier", default="robust", choices=["robust", "aggressive"])
    ap.add_argument("--rule", default="genz_malik", choices=["genz_malik", "gauss_kronrod"])
    ap.add_argument("--use-kernel", action="store_true", help="Pallas GM kernel")
    ap.add_argument(
        "--interpret",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="Pallas interpret mode (keep on for CPU; --no-interpret on TPU)",
    )
    ap.add_argument(
        "--block-regions", type=int, default=0, help="kernel lanes per block (0 = default)"
    )
    ap.add_argument(
        "--eval-window",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="evaluate only the leading active window of the region store",
    )
    ap.add_argument(
        "--eval-window-min", type=int, default=256, help="smallest window ladder rung"
    )
    ap.add_argument(
        "--advance-window",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="window the advance stage (classify + split/compact + global "
        "reductions) as well — bit-identical, scales the whole iteration "
        "with the live population",
    )
    ap.add_argument("--max-iters", type=int, default=600)
    ap.add_argument(
        "--backend",
        default="cubature",
        choices=["cubature", "vegas", "auto"],
        help="cubature (deterministic subdivision), vegas (adaptive "
        "importance-sampling MC for high d), or auto (picks by dimension)",
    )
    ap.add_argument(
        "--mc-samples", type=int, default=8192, help="vegas samples per iteration"
    )
    ap.add_argument(
        "--mc-iters", type=int, default=100, help="vegas iteration cap"
    )
    ap.add_argument(
        "--mc-seed", type=int, default=0, help="vegas PRNG seed (deterministic)"
    )
    ap.add_argument(
        "--theta-seed",
        type=int,
        default=0,
        help="theta draw for a bare family-name --integrand (e.g. "
        "'genz_gaussian' without coefficients)",
    )
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--message-cap", type=int, default=512)
    ap.add_argument(
        "--redistribution",
        default="ring",
        choices=["ring", "off"],
        help="distributed load redistribution policy",
    )
    ap.add_argument(
        "--sync-every",
        type=int,
        default=4,
        help="iterations fused per dispatch in the distributed driver",
    )
    ap.add_argument("--device-loop", action="store_true", help="lax.while_loop driver")
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="write a Chrome trace-event file of the run (Perfetto-loadable)",
    )
    ap.add_argument(
        "--metrics",
        default=None,
        metavar="PATH",
        help="stream telemetry events to PATH as JSON Lines",
    )
    ap.add_argument(
        "--telemetry-summary",
        action="store_true",
        help="print the end-of-run counter/span summary table",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit non-zero unless the result is finite AND converged "
        "(for scripted runs: a NaN or a max_iters/capacity/nonfinite "
        "termination must fail the pipeline, not print and exit 0)",
    )
    args = ap.parse_args()

    if args.devices > 1 and os.environ.get("_REPRO_INT_WORKER") != "1":
        env = dict(os.environ)
        env["_REPRO_INT_WORKER"] = "1"
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + env.get("XLA_FLAGS", "")
        )
        sys.exit(os.spawnvpe(os.P_WAIT, sys.executable, [sys.executable] + sys.argv, env))

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.core import QuadratureConfig, integrate, integrate_device
    from repro.core.distributed import integrate_distributed
    from repro.core.integrands import PARAM_REGISTRY, REGISTRY, bind, get

    # A bare family name (no ':'-separated coefficients) samples one theta
    # deterministically — the ergonomic path for "just integrate a d=15
    # genz_gaussian"; the bound integrand carries its analytic exact value.
    bound = None
    if args.integrand in PARAM_REGISTRY:
        family = PARAM_REGISTRY[args.integrand]
        theta = family.sample_theta(args.d, np.random.default_rng(args.theta_seed))
        bound = bind(family, theta)

    cfg = QuadratureConfig(
        d=args.d,
        integrand=args.integrand,
        rel_tol=args.rel_tol,
        capacity=args.capacity,
        classifier=args.classifier,
        rule=args.rule,
        use_kernel=args.use_kernel,
        interpret=args.interpret,
        block_regions=args.block_regions,
        eval_window=args.eval_window,
        eval_window_min=args.eval_window_min,
        advance_window=args.advance_window,
        max_iters=args.max_iters,
        backend=args.backend,
        mc_samples=args.mc_samples,
        mc_max_iters=args.mc_iters,
        mc_seed=args.mc_seed,
        message_cap=args.message_cap,
        redistribution=args.redistribution,
        sync_every=args.sync_every,
    )
    from repro.telemetry import (
        NULL,
        JsonlSink,
        MemorySink,
        Recorder,
        summary_table,
    )
    from repro.telemetry.trace import write_chrome_trace

    recorder = NULL
    trace_sink = None
    if args.trace or args.metrics or args.telemetry_summary:
        sinks = []
        if args.trace:
            trace_sink = MemorySink()
            sinks.append(trace_sink)
        if args.metrics:
            sinks.append(JsonlSink(args.metrics))
        recorder = Recorder(sinks=tuple(sinks))

    fn = bound.fn if bound is not None else None
    if cfg.resolved_backend() == "vegas":
        from repro.mc import integrate_vegas, integrate_vegas_distributed

        if args.devices > 1:
            res = integrate_vegas_distributed(cfg, fn, recorder=recorder)
            print(res.summary())
            print(f"devices={args.devices} (sample shards split across mesh)")
        else:
            res = integrate_vegas(cfg, fn, recorder=recorder)
            print(res.summary())
    elif args.devices > 1:
        res = integrate_distributed(cfg, fn, recorder=recorder)
        print(res.summary())
        print(f"devices={res.n_devices} mean_imbalance={res.mean_imbalance():.3f}")
    elif args.device_loop:
        res = integrate_device(cfg, fn, recorder=recorder)
        print(res.summary())
    else:
        res = integrate(cfg, fn, recorder=recorder)
        print(res.summary())

    if recorder is not NULL:
        recorder.close()
        if args.trace:
            write_chrome_trace(args.trace, trace_sink.events)
            print(f"wrote Chrome trace: {args.trace}")
        if args.metrics:
            print(f"wrote metrics JSONL: {args.metrics}")
        if args.telemetry_summary:
            print(summary_table(recorder))
    exact = None
    if bound is not None:
        exact = bound.exact(args.d)
    elif args.integrand in REGISTRY or ":" in args.integrand:
        # fixed registry entries and family specs (e.g. genz_gaussian:5,5:.3,.7)
        exact = get(args.integrand).exact(args.d)
    if exact is not None:
        rel = abs(res.integral - exact) / max(abs(exact), 1e-300)
        print(f"exact={exact:.15e} true_rel_err={rel:.3e}")

    if args.strict:
        import math

        problems = []
        if not (math.isfinite(res.integral) and math.isfinite(res.error)):
            problems.append(
                f"non-finite result (integral={res.integral!r}, "
                f"error={res.error!r})"
            )
        if res.status != "converged":
            hints = {
                "max_iters": "raise --max-iters (or --mc-iters for vegas), "
                "or loosen --rel-tol",
                "capacity": "raise --capacity or loosen --rel-tol",
                "nonfinite": "the integrand produced NaN/Inf on this domain; "
                "check the integrand/theta for poles or overflow",
                "no_active": "the region population collapsed; loosen "
                "--rel-tol",
            }
            hint = hints.get(res.status, "see the status taxonomy in DESIGN.md")
            problems.append(f"status={res.status!r} (hint: {hint})")
        if problems:
            print(
                "STRICT: " + "; ".join(problems),
                file=sys.stderr,
            )
            sys.exit(1)


if __name__ == "__main__":
    main()
