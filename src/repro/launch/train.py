"""Production training launcher.

On a real TPU slice this runs under `jax.distributed.initialize()` with the
production mesh; on this CPU container it runs reduced configs single-device
(the dry-run proves the full-mesh path).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 20 [--mesh single|multi|none] [--zero1] [--grad-compression bf16_ef]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.synthetic import DataConfig, batch_for_step, frame_batch_for_step
from repro.models.model import model_init
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train import TrainConfig, make_train_step


def build_batch(cfg, dc, step):
    if cfg.family == "audio":
        return frame_batch_for_step(dc, step, cfg.d_model)
    return batch_for_step(dc, step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--grad-compression", default="none", choices=["none", "bf16_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "vlm" and args.seq <= cfg.n_frontend_tokens:
        raise SystemExit("--seq must exceed the VLM frontend token count")

    tcfg = TrainConfig(
        remat=args.remat,
        microbatches=args.microbatches,
        opt=OptimizerConfig(
            lr=args.lr,
            warmup_steps=max(2, args.steps // 10),
            total_steps=args.steps,
            zero1=args.zero1,
            grad_compression=args.grad_compression,
        ),
    )
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    params = model_init(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(tcfg.opt, params)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if mgr and mgr.latest_step() is not None:
        restored, start = mgr.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed at step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, {len(jax.devices())} device(s)")
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in build_batch(cfg, dc, step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss {float(metrics['ce_loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.2f}",
                flush=True,
            )
        if mgr and step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print(f"trained {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
