"""Deterministic synthetic data pipeline.

Replayable-by-step: ``batch_for_step(step)`` is a pure function of
(seed, step, shard), so any host can be replaced after a failure and
regenerate exactly its shard of the stream (the fault-tolerance contract in
DESIGN.md §6).  The token stream has learnable low-order structure (a noisy
modular-affine walk) so short training runs show a decreasing loss.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int  # per-host batch
    seq_len: int
    seed: int = 0
    noise: float = 0.1
    n_hosts: int = 1
    host_id: int = 0


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    """Returns {"tokens": (B, S) int32, "labels": (B, S) int32}."""
    rng = np.random.Generator(
        np.random.Philox(key=[cfg.seed * 0x9E3779B1 + cfg.host_id, step])
    )
    b, s, v = cfg.batch, cfg.seq_len, cfg.vocab_size
    start = rng.integers(0, v, size=(b, 1))
    stride = rng.integers(1, min(v - 1, 7) + 1, size=(b, 1))
    seq = (start + stride * np.arange(s + 1)[None, :]) % v
    flip = rng.random((b, s + 1)) < cfg.noise
    noise_tok = rng.integers(0, v, size=(b, s + 1))
    seq = np.where(flip, noise_tok, seq).astype(np.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def frame_batch_for_step(cfg: DataConfig, step: int, d_model: int) -> dict:
    """[audio]/[vlm] stub frontend: precomputed embeddings + frame labels."""
    rng = np.random.Generator(
        np.random.Philox(key=[cfg.seed * 0x85EBCA77 + cfg.host_id, step])
    )
    b, s, v = cfg.batch, cfg.seq_len, cfg.vocab_size
    labels = rng.integers(0, v, size=(b, s)).astype(np.int32)
    # embeddings carry the label signal so the head can learn
    proto = rng.standard_normal((v, d_model)).astype(np.float32)
    embeds = proto[labels] + 0.5 * rng.standard_normal((b, s, d_model)).astype(
        np.float32
    )
    return {"embeds": embeds, "labels": labels}
