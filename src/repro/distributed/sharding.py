"""Mesh-aware sharding rules: logical activation/parameter axes -> mesh axes.

One rules table maps *logical* axis names to mesh axes; `shard()` applies a
constraint only when a mesh context is active, so the same model code runs
single-device (tests) and multi-pod (dry-run/production) unchanged.

Divisibility fallback: a dimension that does not divide by its mesh-axis
size is replicated instead (GSPMD padding wastes memory silently; an
explicit fallback keeps `memory_analysis` honest and is reported by
`explain()` so the roofline table can show where TP degraded).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),  # flattened (B*S) token axis (MoE routing)
    "seq": None,
    "embed": None,  # d_model on ACTIVATIONS: replicated (pure TP residual)
    "fsdp": "data",  # d_model/large dim on PARAMETERS: FSDP over the data
    #                  axis (weights gathered per-layer, grads reduce-
    #                  scattered) — without this, >30B-param archs cannot
    #                  fit 16 GB/chip (iteration-0 dry-run: qwen3-moe needed
    #                  58 GB/chip for fp32 params alone)
    "heads": "model",  # attention heads (TP)
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",  # MLP hidden (TP column/row pair)
    "vocab": "model",  # logits/head vocab dim (matmul — shards cleanly)
    "vocab_rows": "model",  # embedding-table ROW dim: gather-accessed; serving
    #                         overrides to None (SPMD lowers a gather from a
    #                         row-sharded table by replicating the table)
    "experts": "model",  # expert parallelism
    "expert_cap": ("pod", "data"),  # dispatch-buffer token-capacity dim
    "ssm_inner": "model",  # mamba d_inner / conv channels
    "ssm_heads": "model",
    "state": None,
    "kv_seq": None,  # KV-cache sequence dim (long-context variant: "model")
    "mla_rank": None,  # MLA latent rank dim (decode hillclimb: "model" — the
    #                    per-token cache INSERT stays local, the per-block
    #                    score contraction pays a small psum instead)
    "lora": None,
    "zero1": ("pod", "data"),  # optimizer-state sharding (ZeRO-1)
}

_CTX = threading.local()


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES, **(rules or {}))
        self.fallbacks: list[tuple[str, int, int]] = []

    def axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))

    def spec(self, dims: tuple[int, ...], names: tuple[Optional[str], ...]) -> P:
        assert len(dims) == len(names), (dims, names)
        used: set[str] = set()
        parts = []
        for dim, name in zip(dims, names):
            mesh_axes = self.rules.get(name) if name else None
            if mesh_axes is None:
                parts.append(None)
                continue
            axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            axes = tuple(a for a in axes if a in self.mesh.shape and a not in used)
            size = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
            if not axes or size <= 1:
                parts.append(None)
                continue
            if dim % size != 0:
                self.fallbacks.append((name or "?", dim, size))
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
        return P(*parts)

    def named(self, dims, names) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(dims, names))


@contextmanager
def mesh_rules(mesh: Mesh, rules: Optional[dict] = None):
    prev = getattr(_CTX, "ctx", None)
    _CTX.ctx = ShardingCtx(mesh, rules or {})
    try:
        yield _CTX.ctx
    finally:
        _CTX.ctx = prev


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_CTX, "ctx", None)


def shard(x, *names):
    """Constrain activation x to the logical axes `names` (None = replicate)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = ctx.spec(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# --- parameter sharding rules (by pytree path suffix) -------------------------

_PARAM_AXES = [
    # (path fragment, logical axes per dim) — two-axis (FSDP x TP) sharding
    ("embed/table", ("vocab_rows", "fsdp")),
    ("head/w", ("fsdp", "vocab")),
    ("wq_a", ("fsdp", "lora")),
    ("wq_b", ("lora", "heads_flat")),
    ("w_kv_a", ("fsdp", "lora")),
    ("w_k_b", ("lora", "heads_flat")),
    ("w_v_b", ("lora", "heads_flat")),
    ("wq", ("fsdp", "heads_flat")),
    ("wk", ("fsdp", "kv_flat")),
    ("wv", ("fsdp", "kv_flat")),
    ("wo", ("heads_flat", "fsdp")),
    ("w_gate", None),  # resolved by rank below (dense vs expert)
    ("w_up", None),
    ("w_down", None),
    ("router", ("fsdp", None)),
    ("in_proj", ("fsdp", "ssm_inner")),
    ("out_proj", ("ssm_inner", "fsdp")),
    ("conv_w", (None, "ssm_inner")),
]

# flattened head projections: output dim = heads * head_dim -> shard on model
_EXTRA_RULES = {"heads_flat": "model", "kv_flat": "model"}


def param_logical_axes(path: str, shape: tuple[int, ...]):
    """Logical axes for a parameter leaf, by name + rank heuristics."""
    for frag, axes in _PARAM_AXES:
        if path.endswith(frag) or f"/{frag}" in path:
            if axes is not None:
                return axes
            # w_gate / w_up / w_down: dense (2-D) vs expert (3-D).
            # Expert FFNs put the FSDP axis on the FFN dim, not d_model:
            # d_model is the einsum contraction dim and sharding it makes
            # SPMD gather the weights (60 GiB/chip on MoE decode); sharding
            # f keeps the contraction local and the combine a small AR.
            if len(shape) == 3:
                if path.endswith("w_down") or "/w_down" in path:
                    return ("experts", "fsdp", None)
                return ("experts", None, "fsdp")
            if path.endswith("w_down") or "/w_down" in path:
                return ("ff", "fsdp")
            return ("fsdp", "ff")
    return tuple(None for _ in shape)  # norms, scalars: replicated


def param_spec_tree(params_shape, mesh: Mesh, rules: Optional[dict] = None):
    """PartitionSpec pytree for a (possibly abstract) params pytree."""
    ctx = ShardingCtx(mesh, dict(_EXTRA_RULES, **(rules or {})))

    def leaf_spec(path, leaf):
        pathstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        # Scanned layer stacks carry a leading period axis: strip it BEFORE
        # the name/rank matching (a stacked expert tensor is 4-D and a
        # stacked dense MLP is 3-D — rank heuristics on the stacked shape
        # mis-assign both), then re-prepend a replicated axis.
        stacked = "layers/" in pathstr or pathstr.startswith("layers")
        base_shape = leaf.shape[1:] if stacked and leaf.ndim >= 2 else leaf.shape
        names = param_logical_axes(pathstr, base_shape)
        if len(names) != len(base_shape):
            names = tuple(None for _ in base_shape)
        if base_shape is not leaf.shape:
            names = (None,) + tuple(names)
        return ctx.spec(leaf.shape, names)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)
