"""Fig. 3b analogue: speedup of distributed GM (2 devices) over the
single-device PAGANI-style aggressive baseline at matched (tolerance, d)."""

from benchmarks._common import run_worker, save_results


def run(fast: bool = True):
    grid = [("f1", 3, 1e-6), ("f4", 3, 1e-6)] if fast else [
        ("f1", 4, 1e-7),
        ("f2", 4, 1e-6),
        ("f4", 4, 1e-7),
        ("f6", 3, 1e-6),
    ]
    out = []
    for name, d, tol in grid:
        base = run_worker(
            {
                "n_devices": 1,
                "cases": [
                    dict(
                        integrand=name, d=d, rel_tol=tol, capacity=1 << 15,
                        classifier="aggressive", max_iters=300, distributed=False,
                    )
                ],
            }
        )[0]
        dist = run_worker(
            {
                "n_devices": 2,
                "cases": [
                    dict(
                        integrand=name, d=d, rel_tol=tol, capacity=1 << 14,
                        max_iters=300, distributed=True,
                    )
                ],
            }
        )[0]
        out.append(
            {
                "integrand": name,
                "d": d,
                "rel_tol": tol,
                "baseline": base,
                "distributed": dist,
                "speedup_evals": base["n_evals"] / max(dist["n_evals"], 1),
                "speedup_wall": base["wall_s"] / max(dist["wall_s"], 1e-9),
            }
        )
    save_results("fig3b_speedup", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"fig3b/{r['integrand']}_d{r['d']}",
            r["distributed"]["wall_s"] * 1e6,
            f"speedup_evals={r['speedup_evals']:.2f};speedup_wall={r['speedup_wall']:.2f}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
