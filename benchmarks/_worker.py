"""Subprocess worker for multi-device quadrature benchmarks.

Usage: python -m benchmarks._worker '<json spec>'
spec = {"n_devices": int, "cases": [{integrand, d, rel_tol, capacity,
        classifier, redistribution, max_iters, use_kernel}]}
Prints one line: RESULT_JSON:[...per-case records...]
"""

import json
import os
import sys
import time


def main() -> None:
    spec = json.loads(sys.argv[1])
    n_dev = int(spec.get("n_devices", 1))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={max(n_dev, 1)} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core import integrands
    from repro.core.adaptive import integrate
    from repro.core.config import QuadratureConfig
    from repro.core.distributed import integrate_distributed

    out = []
    for case in spec["cases"]:
        case = dict(case)
        distributed = case.pop("distributed", n_dev > 1)
        cfg = QuadratureConfig(**case)
        t0 = time.time()
        if cfg.resolved_backend() == "vegas":
            from repro.mc import integrate_vegas, integrate_vegas_distributed

            if distributed:
                res = integrate_vegas_distributed(cfg)
            else:
                res = integrate_vegas(cfg)
            extra = {"chi2_dof": res.chi2_dof}
        elif distributed:
            res = integrate_distributed(cfg)
            extra = {
                "mean_imbalance": res.mean_imbalance(),
                "evals_per_device": res.evals_per_device.tolist(),
                "history_tail": res.history[-3:],
            }
        else:
            res = integrate(cfg)
            extra = {}
        wall = time.time() - t0
        exact = integrands.get(cfg.integrand).exact(cfg.d)
        out.append(
            {
                **case,
                "n_devices": n_dev if distributed else 1,
                "integral": res.integral,
                "eps": res.error,
                "status": res.status,
                "iterations": res.iterations,
                "n_evals": res.n_evals,
                "wall_s": wall,
                "exact": exact,
                "rel_err": abs(res.integral - exact) / max(abs(exact), 1e-300),
                **extra,
            }
        )
    print("RESULT_JSON:" + json.dumps(out))


if __name__ == "__main__":
    main()
