"""Per-iteration rule-eval cost vs active count: full store vs active window.

Quantifies the tentpole claim of the active-window refactor: with the legacy
path every iteration pays for all ``capacity`` slots, so early/late
iterations with few live regions burn orders of magnitude more FLOPs than
needed; the windowed path evaluates only the smallest ladder rung covering
the live population.
"""

import dataclasses
import time

import jax
import numpy as np


def _timeit(fn, state, reps: int) -> float:
    fn(state).est.block_until_ready()  # warmup / compile
    t0 = time.time()
    for _ in range(reps):
        fn(state).est.block_until_ready()
    return (time.time() - t0) / reps


def run(fast: bool = True):
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import region_store
    from repro.core.adaptive import make_eval_step
    from repro.core.config import QuadratureConfig
    from repro.core.rules import make_rule

    d = 5
    capacity = 1 << 13 if fast else 1 << 14
    cfg = QuadratureConfig(d=d, integrand="f4", capacity=capacity).validate()
    rule = make_rule(cfg)
    ladder = region_store.window_ladder(capacity, cfg.eval_window_min)
    full = jax.jit(make_eval_step(cfg, rule))

    rng = np.random.default_rng(0)
    reps = 3 if fast else 10
    actives = sorted({64, 256, 1024, capacity // 16, capacity // 4, capacity})
    out = []
    for n_active in actives:
        centers = np.zeros((capacity, d))
        halfw = np.zeros((capacity, d))
        centers[:n_active] = rng.uniform(0.2, 0.8, (n_active, d))
        halfw[:n_active] = rng.uniform(0.01, 0.1, (n_active, d))
        mask = np.arange(capacity) < n_active
        state = dataclasses.replace(
            region_store.empty_state(capacity, d, jnp.float64),
            centers=jnp.asarray(centers),
            halfw=jnp.asarray(halfw),
            active=jnp.asarray(mask),
            fresh=jnp.asarray(mask),
        )
        window = region_store.select_window(ladder, n_active)
        windowed = jax.jit(make_eval_step(cfg, rule, window=window))
        t_full = _timeit(full, state, reps)
        t_win = _timeit(windowed, state, reps)
        out.append(
            {
                "d": d,
                "capacity": capacity,
                "n_active": n_active,
                "window": window,
                "full_us": t_full * 1e6,
                "windowed_us": t_win * 1e6,
                "speedup": t_full / t_win,
            }
        )
    from benchmarks._common import save_results

    save_results("eval_window", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"eval_window/d{r['d']}_C{r['capacity']}_n{r['n_active']}",
            r["windowed_us"],
            f"full_us={r['full_us']:.0f};window={r['window']};"
            f"speedup={r['speedup']:.1f}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
