"""Assemble EXPERIMENTS.md tables from results/ JSON artifacts.

Usage: PYTHONPATH=src:. python -m benchmarks.render_experiments
Writes markdown fragments to results/fragments/*.md which EXPERIMENTS.md
references (and inlines at final render).
"""

from __future__ import annotations

import glob
import json
import os

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(pattern):
    out = []
    for path in sorted(glob.glob(os.path.join(_REPO, pattern))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def dryrun_table(dirname="results/dryrun") -> str:
    recs = _load(f"{dirname}/*.json")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    rows = [
        "| arch | shape | mesh | status | compile (s) | HLO GFLOP/chip | "
        "temp GiB/chip | collectives MiB/chip | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | — | — | — | — "
                f"| {r['reason']} |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | — | — | — | — "
                f"| {r.get('error','')[:80]} |"
            )
            continue
        coll = sum(v for k, v in r.get("collectives", {}).items() if k != "count")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {r.get('flops', 0)/1e9:.1f} "
            f"| {r.get('temp_size_in_bytes', 0)/2**30:.2f} "
            f"| {coll/2**20:.0f} | |"
        )
    return "\n".join(rows)


def roofline_table(dirname="results/roofline") -> str:
    recs = _load(f"{dirname}/*.json")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    rows = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO FLOPs | compute frac of bound | what would move the bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        "compute": "more chips or lower-precision matmuls",
        "memory": "shrink the working set (cache dtype/sharding, fusion)",
        "collective": "reshard to cut gather volume / overlap with compute",
    }
    for r in recs:
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | {r['reason']} |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | FAIL | — | — "
                f"| {r.get('error','')[:60]} |"
            )
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} | "
            f"{hints[r['dominant']]} |"
        )
    return "\n".join(rows)


def bench_tables() -> str:
    chunks = []
    for name in (
        "fig2a_runtime",
        "fig2b_accuracy",
        "fig3a_feasibility",
        "fig3b_speedup",
        "fig4a_scaling",
        "fig4b_idle",
        "kernel_bench",
    ):
        path = os.path.join(_REPO, "results", "benchmarks", f"{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            recs = json.load(f)
        chunks.append(f"### {name}\n```json\n{json.dumps(recs, indent=1)[:6000]}\n```")
    return "\n\n".join(chunks)


def main() -> None:
    frag_dir = os.path.join(_REPO, "results", "fragments")
    os.makedirs(frag_dir, exist_ok=True)
    with open(os.path.join(frag_dir, "dryrun_table.md"), "w") as f:
        f.write(dryrun_table())
    with open(os.path.join(frag_dir, "dryrun_iter0_table.md"), "w") as f:
        f.write(dryrun_table("results/dryrun_iter0_baseline"))
    with open(os.path.join(frag_dir, "roofline_table.md"), "w") as f:
        f.write(roofline_table())
    print("fragments written to", frag_dir)


if __name__ == "__main__":
    main()
