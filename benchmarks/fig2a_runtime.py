"""Fig. 2a analogue: runtime/evaluations vs tolerance, GM (robust) vs the
PAGANI-style aggressive-pruning baseline, single device.

Paper claims reproduced: the robust GM solver converges on oscillatory f1 at
every tolerance while the aggressive baseline stalls/fails at tight
tolerances; the baseline is competitive on peaked integrands (f2)."""

from benchmarks._common import run_worker, save_results

FAST_GRID = dict(ds={"f1": 3, "f4": 3, "f6": 3}, tols=(1e-4, 1e-6, 1e-8))
FULL_GRID = dict(
    ds={"f1": 5, "f2": 5, "f4": 5, "f6": 4},
    tols=(1e-3, 1e-4, 1e-5, 1e-6, 1e-7, 1e-8, 1e-9, 1e-10),
)


def run(fast: bool = True):
    grid = FAST_GRID if fast else FULL_GRID
    cases = []
    for name, d in grid["ds"].items():
        for tol in grid["tols"]:
            for classifier in ("robust", "aggressive"):
                cases.append(
                    dict(
                        integrand=name,
                        d=d,
                        rel_tol=tol,
                        capacity=1 << 15,
                        classifier=classifier,
                        max_iters=200,
                        distributed=False,
                    )
                )
    recs = run_worker({"n_devices": 1, "cases": cases})
    save_results("fig2a_runtime", recs)
    return recs


def rows(recs):
    for r in recs:
        yield (
            f"fig2a/{r['integrand']}_d{r['d']}_{r['classifier']}_tol{r['rel_tol']:.0e}",
            r["wall_s"] * 1e6,
            f"evals={r['n_evals']:.3g};status={r['status']}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
