"""High-dimensional feasibility: the cubature/VEGAS crossover.

The paper's deterministic rules pay O(2^d + 2 d^2 + 2 d + 1) integrand
evaluations per region, so past d ≈ 8-10 the region store (and eventually a
single rule evaluation) stops fitting in memory; the VEGAS backend's cost
per sample is dimension-independent.  This benchmark measures both backends
on the two Genz families at d ∈ {5, 8, 10, 15, 20} (fast: {5, 10, 15}) at
rel_tol 1e-3 and records status / true error / wall time, giving the
crossover the ``backend="auto"`` dimension threshold approximates.

Cubature cases whose *initial evaluation* alone would exceed the memory
guard are recorded as ``infeasible`` without being run (that is the point:
at d = 15 one Genz-Malik sweep of the initial partition needs ~TBs), as are
cases that crash or time out in the worker subprocess.
"""

import subprocess

from benchmarks._common import run_worker, save_results

REL_TOL = 1e-3
# bytes of *one* (nodes, regions) value matrix of the initial partition,
# beyond which cubature is recorded infeasible without being attempted (the
# reference evaluator materialises several of these, so the real footprint
# is a small multiple — and past this size the sweep also times out)
OOM_GUARD_BYTES = 512 << 20


def _spec(family: str, d: int) -> str:
    a = ",".join(["5"] * d)
    u = ",".join(["0.5"] * d)
    return f"{family}:{a}:{u}"


def _cubature_est_bytes(d: int, capacity: int) -> int:
    from repro.core import genz_malik
    from repro.core.config import QuadratureConfig

    n_init = QuadratureConfig(d=d, capacity=capacity).resolved_n_init()
    # the reference evaluator materialises (nodes, regions) value matrices
    return genz_malik.n_nodes(d) * n_init * 8


def _run_case(case: dict, timeout: int) -> dict:
    try:
        (rec,) = run_worker({"n_devices": 1, "cases": [case]}, timeout=timeout)
        return rec
    except (RuntimeError, subprocess.TimeoutExpired) as e:
        return {
            **case,
            "status": "infeasible",
            "rel_err": None,
            "wall_s": None,
            "detail": type(e).__name__,
        }


def run(fast: bool = True):
    dims = (5, 10, 15) if fast else (5, 8, 10, 15, 20)
    timeout = 300 if fast else 1200
    capacity = 1 << 14
    out = []
    for family in ("genz_gaussian", "genz_product_peak"):
        for d in dims:
            spec = _spec(family, d)
            for backend in ("cubature", "vegas"):
                case = {
                    "integrand": spec,
                    "d": d,
                    "rel_tol": REL_TOL,
                    "backend": backend,
                }
                if backend == "cubature":
                    case.update(capacity=capacity, max_iters=60 if fast else 200)
                    if _cubature_est_bytes(d, capacity) > OOM_GUARD_BYTES:
                        out.append(
                            {
                                **case,
                                "status": "infeasible",
                                "rel_err": None,
                                "wall_s": None,
                                "detail": "oom_guard",
                            }
                        )
                        continue
                else:
                    case.update(
                        mc_samples=16384, mc_max_iters=40 if fast else 100
                    )
                out.append(_run_case(case, timeout))
    save_results("highdim_feasibility", out)
    return out


def rows(recs):
    for r in recs:
        wall = r.get("wall_s")
        rel = r.get("rel_err")
        yield (
            f"highdim/{r['integrand'].split(':')[0]}_d{r['d']}_{r['backend']}",
            0.0 if wall is None else wall * 1e6,
            f"status={r['status']} rel_err={'n/a' if rel is None else f'{rel:.1e}'}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
