"""GM Pallas kernel benchmark: interpret-mode correctness timing vs the
pure-jnp oracle + the analytic VMEM/arithmetic-intensity roofline of the
kernel on the v5e target."""

import time

import jax
import numpy as np


def run(fast: bool = True):
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import integrands
    from repro.core.genz_malik import n_nodes
    from repro.kernels import ops
    from repro.kernels.ref import genz_malik_eval_soa_ref

    out = []
    dims = (3, 5) if fast else (2, 3, 5, 8, 10)
    b = 1024 if fast else 4096
    rng = np.random.default_rng(0)
    f = integrands.get("f4").fn
    for d in dims:
        centers = jnp.asarray(rng.uniform(0.1, 0.9, (b, d)))
        halfw = jnp.asarray(rng.uniform(0.01, 0.1, (b, d)))

        k_fn = jax.jit(lambda c, h: ops.genz_malik_eval(f, c, h, interpret=True)[0])
        r_fn = jax.jit(lambda c, h: genz_malik_eval_soa_ref(f, c.T, h.T)[0])
        k_fn(centers, halfw).block_until_ready()
        r_fn(centers, halfw).block_until_ready()
        t0 = time.time(); k_fn(centers, halfw).block_until_ready(); tk = time.time() - t0
        t0 = time.time(); r_fn(centers, halfw).block_until_ready(); tr = time.time() - t0

        # analytic kernel roofline on TPU v5e (f32):
        nodes = n_nodes(d)
        flops_per_region = nodes * (6 * d + 4) + 8 * nodes  # node gen + f4 + sums
        bytes_per_region = (2 * d + 3 + d) * 4  # c,h in; i7,i5,i3,diffs out
        intensity = flops_per_region / bytes_per_region
        ridge = 197e12 / 819e9  # v5e flops/byte ridge point ~ 240
        out.append(
            {
                "d": d,
                "batch": b,
                "n_nodes": nodes,
                "interpret_us": tk * 1e6,
                "ref_us": tr * 1e6,
                "arith_intensity": intensity,
                "compute_bound_on_v5e": intensity > ridge,
            }
        )
    from benchmarks._common import save_results

    save_results("kernel_bench", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"kernel/gm_d{r['d']}_b{r['batch']}",
            r["interpret_us"],
            f"intensity={r['arith_intensity']:.0f};compute_bound={r['compute_bound_on_v5e']}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
