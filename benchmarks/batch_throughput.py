"""Fleet throughput: serial `integrate` loop vs the continuous-batching engine.

The quantity a quadrature *service* cares about is problems/sec over a fleet
of related integrals.  The serial loop pays per-problem dispatch overhead
(one small XLA launch per iteration per problem) and leaves the hardware
under-occupied on small populations; the batch engine vmaps the adaptive
step over `batch_slots` problems so every dispatch carries B problems'
worth of regions, and continuous batching keeps the slots full as
heterogeneous problems converge at different iterations.

Reports problems/sec at B in {8, 32, 128} for both paths (same thetas, same
tolerances) plus the speedup; records land in results/benchmarks/.

The serial baseline re-traces `integrate`'s jitted steps for every problem
(each theta is a new closure — the seed API has no traced-theta path), so
its cost is dominated by compilation and exactly linear in B; at B = 128 it
is therefore timed on a 16-problem subsample and extrapolated (flagged
``serial_extrapolated`` in the record), while the batch engine is always
timed on the full fleet.
"""

import time

SERIAL_SAMPLE_CAP = 16


def run(fast: bool = True):
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import QuadratureConfig, integrate
    from repro.core.integrands import bind, get_param
    from repro.service.scheduler import BatchScheduler, QuadRequest

    d = 3
    family = get_param("genz_gaussian")
    batches = (8, 32) if fast else (8, 32, 128)
    out = []
    for B in batches:
        cfg = QuadratureConfig(
            d=d,
            integrand="genz_gaussian",
            rel_tol=1e-6,
            capacity=1 << 11,
            batch_slots=min(B, 32),
            max_iters=200,
        )
        rng = np.random.default_rng(1234 + B)
        thetas = [family.sample_theta(d, rng) for _ in range(B)]
        requests = [QuadRequest(req_id=i, theta=t) for i, t in enumerate(thetas)]

        # batch engine: the cold pass pays every window rung's compilation
        # (what a freshly constructed engine costs once); the warm pass
        # reuses the scheduler's compiled engine — what a long-running
        # service pays per fleet.  Report both.
        scheduler = BatchScheduler(cfg, family)
        t0 = time.perf_counter()
        batch_results = sorted(
            scheduler.serve(requests), key=lambda r: r.req_id
        )
        t_batch_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        batch_results = sorted(
            scheduler.serve(requests), key=lambda r: r.req_id
        )
        t_batch = time.perf_counter() - t0

        # serial loop: same config/thetas, one adaptive run per problem
        n_serial = min(B, SERIAL_SAMPLE_CAP)
        serial_results = []
        t0 = time.perf_counter()
        for theta in thetas[:n_serial]:
            serial_results.append(integrate(cfg, bind(family, theta).fn))
        t_serial = (time.perf_counter() - t0) * (B / n_serial)

        for br, sr in zip(batch_results[:n_serial], serial_results):
            assert br.status == sr.status == "converged", (br, sr)
            assert br.integral == sr.integral, "batch/serial parity broken"
        out.append(
            {
                "B": B,
                "d": d,
                "batch_slots": cfg.batch_slots,
                "rel_tol": cfg.rel_tol,
                "capacity": cfg.capacity,
                "serial_s": t_serial,
                "serial_extrapolated": n_serial < B,
                "batch_s": t_batch,
                "batch_cold_s": t_batch_cold,
                "serial_problems_per_s": B / t_serial,
                "batch_problems_per_s": B / t_batch,
                "speedup": t_serial / t_batch,
                "speedup_cold": t_serial / t_batch_cold,
            }
        )
        from benchmarks._common import save_results

        save_results("batch_throughput", out)  # incremental: keep partial runs
    return out


def rows(recs):
    for r in recs:
        yield (
            f"batch_throughput/B{r['B']}_slots{r['batch_slots']}",
            r["batch_s"] / r["B"] * 1e6,
            f"problems_per_s={r['batch_problems_per_s']:.2f};"
            f"serial_problems_per_s={r['serial_problems_per_s']:.2f};"
            f"speedup={r['speedup']:.2f};speedup_cold={r['speedup_cold']:.2f}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
