"""Fig. 3a analogue: strictest achievable tolerance vs dimension, 1 vs 2
devices.  The region store is the memory proxy (fixed per-device capacity):
multi-device execution extends feasibility because capacity scales with
device count — the paper's central multi-GPU claim."""

from benchmarks._common import run_worker, save_results

TOL_LADDER = (1e-3, 1e-5, 1e-7, 1e-9, 1e-11)


def _strictest(n_dev, name, d, capacity, fast):
    ladder = TOL_LADDER[: 3 if fast else len(TOL_LADDER)]
    cases = [
        dict(
            integrand=name,
            d=d,
            rel_tol=tol,
            capacity=capacity,
            max_iters=60 if fast else 150,
            distributed=n_dev > 1,
        )
        for tol in ladder
    ]
    recs = run_worker({"n_devices": n_dev, "cases": cases})
    best = None
    for r in recs:
        if r["status"] == "converged" and r["rel_err"] <= 10 * r["rel_tol"]:
            best = r["rel_tol"]
    return best, recs


def run(fast: bool = True):
    out = []
    dims = (3, 4) if fast else (3, 4, 5, 6, 7)
    for name in ("f1", "f5"):
        for d in dims:
            for n_dev in (1, 2):
                best, recs = _strictest(n_dev, name, d, 1 << 12, fast)
                out.append(
                    {
                        "integrand": name,
                        "d": d,
                        "n_devices": n_dev,
                        "strictest_tol": best,
                        "runs": recs,
                    }
                )
    save_results("fig3a_feasibility", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"fig3a/{r['integrand']}_d{r['d']}_dev{r['n_devices']}",
            0.0,
            f"strictest_tol={r['strictest_tol']}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
