"""Fig. 3a analogue: strictest achievable tolerance vs dimension.

Cubature runs at 1 and 2 devices (the region store is the memory proxy:
multi-device execution extends feasibility because capacity scales with
device count — the paper's central multi-GPU claim) **plus the VEGAS
backend**, so the figure keeps producing points where cubature runs out of
region store instead of simply dying: past the crossover the strictest
achievable tolerance belongs to the MC backend (its feasibility is bounded
by sample budget, not memory).  See ``benchmarks/highdim_feasibility.py``
for the dedicated high-d crossover sweep.
"""

from benchmarks._common import run_worker, save_results

TOL_LADDER = (1e-3, 1e-5, 1e-7, 1e-9, 1e-11)
# MC error shrinks with the square root of the budget: ladder rungs below
# ~1e-7 would need >1e14 samples, so vegas probes only the reachable rungs
VEGAS_TOLS = (1e-3, 1e-5)


def _strictest(n_dev, name, d, capacity, fast, backend="cubature"):
    if backend == "vegas":
        ladder = VEGAS_TOLS[: 1 if fast else len(VEGAS_TOLS)]
        cases = [
            dict(
                integrand=name,
                d=d,
                rel_tol=tol,
                backend="vegas",
                mc_samples=8192,
                mc_max_iters=40 if fast else 100,
                distributed=False,
            )
            for tol in ladder
        ]
    else:
        ladder = TOL_LADDER[: 3 if fast else len(TOL_LADDER)]
        cases = [
            dict(
                integrand=name,
                d=d,
                rel_tol=tol,
                capacity=capacity,
                max_iters=60 if fast else 150,
                distributed=n_dev > 1,
            )
            for tol in ladder
        ]
    recs = run_worker({"n_devices": n_dev, "cases": cases})
    best = None
    for r in recs:
        if r["status"] == "converged" and r["rel_err"] <= 10 * r["rel_tol"]:
            best = r["rel_tol"]
    return best, recs


def run(fast: bool = True):
    out = []
    dims = (3, 4) if fast else (3, 4, 5, 6, 7)
    for name in ("f1", "f5"):
        for d in dims:
            for n_dev in (1, 2):
                best, recs = _strictest(n_dev, name, d, 1 << 12, fast)
                out.append(
                    {
                        "integrand": name,
                        "d": d,
                        "n_devices": n_dev,
                        "backend": "cubature",
                        "strictest_tol": best,
                        "runs": recs,
                    }
                )
            # vegas: device count does not change feasibility (sample
            # sharding is bit-identical), so one row per (integrand, d)
            best, recs = _strictest(1, name, d, 1 << 12, fast, backend="vegas")
            out.append(
                {
                    "integrand": name,
                    "d": d,
                    "n_devices": 1,
                    "backend": "vegas",
                    "strictest_tol": best,
                    "runs": recs,
                }
            )
    save_results("fig3a_feasibility", out)
    return out


def rows(recs):
    for r in recs:
        backend = r.get("backend", "cubature")
        yield (
            f"fig3a/{r['integrand']}_d{r['d']}_{backend}_dev{r['n_devices']}",
            0.0,
            f"strictest_tol={r['strictest_tol']}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
