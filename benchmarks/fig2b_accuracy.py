"""Fig. 2b analogue: achieved relative error vs requested tolerance for the
whole f1..f7 suite.  Claim: the robust solver meets every requested
tolerance; the aggressive baseline can overshoot on the Gaussian (f4) at
intermediate tolerances (over-optimistic pruning in the tails)."""

from benchmarks._common import run_worker, save_results

SUITE = {"f1": 3, "f2": 3, "f3": 4, "f4": 3, "f5": 3, "f6": 3, "f7": 4}


def run(fast: bool = True):
    tols = (1e-5, 1e-7) if fast else (1e-4, 1e-5, 1e-6, 1e-7, 1e-8, 1e-9)
    cases = []
    for name, d in SUITE.items():
        for tol in tols:
            for classifier in ("robust", "aggressive"):
                cases.append(
                    dict(
                        integrand=name,
                        d=d,
                        rel_tol=tol,
                        capacity=1 << 15,
                        classifier=classifier,
                        max_iters=300,
                        distributed=False,
                    )
                )
    recs = run_worker({"n_devices": 1, "cases": cases})
    save_results("fig2b_accuracy", recs)
    return recs


def rows(recs):
    for r in recs:
        met = r["rel_err"] <= 10 * r["rel_tol"] or r["status"] != "converged"
        yield (
            f"fig2b/{r['integrand']}_d{r['d']}_{r['classifier']}_tol{r['rel_tol']:.0e}",
            r["wall_s"] * 1e6,
            f"rel_err={r['rel_err']:.2e};met={met}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
