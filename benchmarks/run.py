"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the normalized
``results/benchmarks/BENCH_summary.json`` the perf regression gate
(``python -m repro.perf.regress``) consumes.  Default mode runs reduced
grids sized for this CPU container; pass ``--full`` for the figure-scale
grids and ``--roofline`` to include the quadrature roofline sweep
(:mod:`benchmarks.quad_roofline`: measured machine terms + per-kernel
cost catalog — not the retired LM sweep in :mod:`benchmarks.roofline`).
"""

from __future__ import annotations

import argparse
import sys
import traceback
from datetime import datetime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    ap.add_argument(
        "--roofline",
        action="store_true",
        help="include the quad_roofline sweep (machine probes + kernel "
        "cost catalog; refreshes results/perf/)",
    )
    args = ap.parse_args()

    # the runner owns the sweep timestamp: every module saved below carries
    # the same date in its results-file meta header
    from benchmarks import _common

    _common.RUN_DATE = datetime.now().astimezone().isoformat(timespec="seconds")

    from benchmarks import (
        batch_throughput,
        eval_window,
        iteration_window,
        fig2a_runtime,
        fig2b_accuracy,
        fig3a_feasibility,
        fig3b_speedup,
        fig4a_scaling,
        fig4b_idle,
        highdim_feasibility,
        kernel_bench,
        sharded_service,
    )

    modules = {
        "fig2a": fig2a_runtime,
        "fig2b": fig2b_accuracy,
        "fig3a": fig3a_feasibility,
        "fig3b": fig3b_speedup,
        "fig4a": fig4a_scaling,
        "fig4b": fig4b_idle,
        "kernel": kernel_bench,
        "highdim": highdim_feasibility,
        "eval_window": eval_window,
        "iteration_window": iteration_window,
        "batch_throughput": batch_throughput,
        "sharded_service": sharded_service,
    }
    if args.roofline:
        from benchmarks import quad_roofline

        modules["quad_roofline"] = quad_roofline
    if args.only:
        keep = set(args.only.split(","))
        # --only quad_roofline works without also passing --roofline
        if "quad_roofline" in keep and "quad_roofline" not in modules:
            from benchmarks import quad_roofline

            modules["quad_roofline"] = quad_roofline
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    summary: dict[str, float] = {}
    for name, mod in modules.items():
        try:
            recs = mod.run(fast=not args.full)
            for row in mod.rows(recs):
                print(",".join(str(x) for x in row), flush=True)
                try:
                    summary[str(row[0])] = float(row[1])
                except (TypeError, ValueError, IndexError):
                    pass  # non-numeric wall column: skip from the gate
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if summary:
        path = _common.save_bench_summary(
            summary, meta={"modules": sorted(modules), "full": args.full}
        )
        print(f"# BENCH_summary: {path}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
