"""Full-iteration (eval + advance) cost vs active count: full vs windowed.

The tentpole claim of the windowed-advance refactor: PR 1 made rule
*evaluation* scale with the live population, but every driver still paid
full-capacity cost in the advance stage — an O(C log C) argsort plus seven
(C, d)-shaped gathers per iteration, and O(C) classify/global reductions.
This benchmark times one complete iteration (windowed eval + windowed
advance vs full eval + full advance) so the end-to-end speedup of the
active-window ladder is measured, not just its eval half.
"""

import dataclasses
import time

import jax
import numpy as np


def _timeit(fn, state, reps: int) -> float:
    fn(state).est.block_until_ready()  # warmup / compile
    t0 = time.time()
    for _ in range(reps):
        fn(state).est.block_until_ready()
    return (time.time() - t0) / reps


def run(fast: bool = True):
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import region_store
    from repro.core.adaptive import (
        advance_ladder,
        advance_target,
        make_advance_step,
        make_eval_step,
    )
    from repro.core.config import QuadratureConfig
    from repro.core.rules import make_rule

    d = 5
    capacities = [1 << 13] if fast else [1 << 13, 1 << 14]
    reps = 3 if fast else 10
    rng = np.random.default_rng(0)
    out = []
    for capacity in capacities:
        cfg = QuadratureConfig(d=d, integrand="f4", capacity=capacity).validate()
        rule = make_rule(cfg)
        ladder = region_store.window_ladder(capacity, cfg.eval_window_min)
        total_volume = 1.0
        width = np.ones(d)

        def iteration(eval_w, adv_w):
            ev = make_eval_step(cfg, rule, window=eval_w)
            adv = make_advance_step(cfg, total_volume, width, window=adv_w)
            return jax.jit(lambda s: adv(ev(s)))

        full = iteration(None, None)

        for n_active in sorted({64, 256, 1024, capacity // 4}):
            centers = np.zeros((capacity, d))
            halfw = np.zeros((capacity, d))
            centers[:n_active] = rng.uniform(0.2, 0.8, (n_active, d))
            halfw[:n_active] = rng.uniform(0.01, 0.1, (n_active, d))
            mask = np.arange(capacity) < n_active
            state = dataclasses.replace(
                region_store.empty_state(capacity, d, jnp.float64),
                centers=jnp.asarray(centers),
                halfw=jnp.asarray(halfw),
                active=jnp.asarray(mask),
                fresh=jnp.asarray(mask),
            )
            w_eval = region_store.select_window(ladder, n_active)
            w_adv = region_store.select_window(
                advance_ladder(cfg), advance_target(n_active, capacity)
            )
            windowed = iteration(w_eval, w_adv)
            t_full = _timeit(full, state, reps)
            t_win = _timeit(windowed, state, reps)
            out.append(
                {
                    "d": d,
                    "capacity": capacity,
                    "n_active": n_active,
                    "eval_window": w_eval,
                    "advance_window": w_adv,
                    "full_us": t_full * 1e6,
                    "windowed_us": t_win * 1e6,
                    "speedup": t_full / t_win,
                }
            )
    from benchmarks._common import save_results

    save_results("iteration_window", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"iteration_window/d{r['d']}_C{r['capacity']}_n{r['n_active']}",
            r["windowed_us"],
            f"full_us={r['full_us']:.0f};eval_w={r['eval_window']};"
            f"adv_w={r['advance_window']};speedup={r['speedup']:.1f}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
