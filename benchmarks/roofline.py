"""Roofline analysis per (arch x shape) on the single-pod production mesh.

Retained LM-era sweep; the quadrature kernels this repo actually runs are
costed by :mod:`repro.perf.catalog` (``python -m benchmarks.run
--roofline``).  Three terms (seconds, PER DEVICE — the partitioned HLO is
the per-device program), sourced from a measured machine file when one
exists (:func:`resolve_terms`) and from the documented v5e preset below
otherwise:

    compute    = HLO_FLOPs / peak_flops       (preset: 197e12, v5e bf16)
    memory     = HLO_bytes_accessed / mem_bw  (preset: 819e9, HBM)
    collective = per-device collective payload bytes / ici_bw (preset: 50e9)

XLA's HloCostAnalysis counts a while/scan body ONCE regardless of trip
count, so costing the scanned-layers module directly undercounts by the
layer count.  Instead we lower two auxiliary modules per cell:

    P1 = model with ONE period of layers (scan trip count 1 — exact)
    P2 = model with TWO periods, the second unrolled into the prologue
         (scan trip count 1 + unrolled period — exact)

and extrapolate: total = cost(P1) + (n_periods - 1) * (cost(P2) - cost(P1)).
The marginal (P2 - P1) isolates exactly one period INCLUDING its
collectives; embed/head/loss/optimizer live in P1.  Memory comes from the
real dry-run compile (results/dryrun/*.json), not from the auxiliary
modules.

MODEL_FLOPS = 6 * N_active * tokens (train) or 2 * N_active * tokens
(+ attention terms) — the usefulness ratio MODEL_FLOPS / HLO_FLOPs catches
remat/redundancy waste.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

# Documented v5e fallback preset — vendor-sheet numbers for the 256-chip
# production mesh this sweep was originally written against.  These are
# NOT this container's numbers: prefer a measured machine file
# (``python -m repro.perf.machine``), resolved via :func:`resolve_terms`
# below / the ``--machine`` flag.  The values are pinned to
# ``repro.perf.machine.PRESETS["v5e"]`` by a drift test in
# ``tests/test_perf.py``.
PEAK_FLOPS = 197e12  # v5e bf16 peak per chip, FLOP/s
HBM_BW = 819e9  # v5e HBM bandwidth per chip, B/s
ICI_BW = 50e9  # v5e ICI per link, B/s
CHIPS = 256

_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def resolve_terms(machine_path: str | None = None) -> tuple[float, float, float]:
    """(peak_flops, mem_bw, ici_bw) from a machine file, else the v5e preset.

    Resolution order matches ``repro.perf.machine.resolve_machine``: an
    explicit path, then the committed ``results/perf/machine.json``, then
    the v5e preset above.  A measured file with no inter-device probe
    (single device) keeps the preset ICI term so the collective column
    stays defined.
    """
    from repro.perf.machine import resolve_machine

    m = resolve_machine(machine_path, preset="v5e")
    return (
        float(m["peak_flops"]),
        float(m["mem_bw"]),
        float(m["ici_bw"]) if m.get("ici_bw") else ICI_BW,
    )


def _cost_of(cfg, shape_name, mesh, microbatches, remat, rules=None):
    import jax

    from repro.launch.dryrun import build_cell, collective_bytes

    fn, args, shardings, donate = build_cell(
        cfg,
        shape_name,
        mesh,
        microbatches=microbatches,
        remat=remat,
        rules=rules,
    )
    compiled = (
        jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        .lower(*args)
        .compile()
    )
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": sum(v for k, v in coll.items() if k != "count"),
        "coll_by_kind": coll,
    }


def model_flops(cfg, shape) -> float:
    """Analytic useful-FLOPs for the cell (global, all chips)."""
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens
        att = _attention_flops(cfg, b, s, causal=True)
        return flops + 3.0 * att  # fwd + 2x bwd
    if shape.kind == "prefill":
        tokens = b * s
        return 2.0 * n_active * tokens + _attention_flops(cfg, b, s, causal=True)
    # decode: one token, attention reads the whole cache
    flops = 2.0 * n_active * b
    att = _attention_decode_flops(cfg, b, s)
    return flops + att


def _cache_bytes(cfg, shape) -> float:
    """Global KV/latent/SSM cache bytes at full context (bf16)."""
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) == "ssm":
            total += 2.0 * b * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state
            total += 2.0 * b * (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state)
        elif cfg.use_mla:
            total += 2.0 * b * s * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        else:
            total += 2.0 * b * s * 2 * cfg.n_kv_heads * cfg.resolved_head_dim
    return total


def _n_attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")


def _attention_flops(cfg, b, s, causal) -> float:
    la = _n_attn_layers(cfg)
    if la == 0:
        return 0.0
    if cfg.use_mla:
        d_eff = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        per = 4.0 * b * s * s * cfg.n_heads * d_eff
    else:
        hd = cfg.resolved_head_dim
        per = 4.0 * b * s * s * cfg.n_heads * hd
    if causal:
        per *= 0.5
    return per * la


def _attention_decode_flops(cfg, b, s_cache) -> float:
    la = _n_attn_layers(cfg)
    if la == 0:
        return 0.0
    if cfg.use_mla:
        d_eff = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        per = 4.0 * b * s_cache * cfg.n_heads * d_eff
    else:
        per = 4.0 * b * s_cache * cfg.n_heads * cfg.resolved_head_dim
    return per * la


def analyse_cell(
    arch: str,
    shape_name: str,
    *,
    microbatches: int = 8,
    remat: str = "full",
    dryrun_dir: str = "results/dryrun",
    rules=None,
    terms: tuple[float, float, float] | None = None,
):
    """Returns the roofline record for one cell on the (16,16) mesh."""
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, cell_status

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_status(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=False)
    period = cfg.block_pattern_period
    n_periods = (cfg.n_layers - cfg.first_k_dense) // period

    # P1: one period; P2: two periods with one unrolled in the prologue.
    cfg_p1 = dataclasses.replace(cfg, n_layers=period, first_k_dense=0)
    cfg_p2 = dataclasses.replace(cfg, n_layers=2 * period, first_k_dense=period)
    mb = microbatches if shape.kind == "train" else 1
    c1 = _cost_of(cfg_p1, shape_name, mesh, 1, remat, rules)
    c2 = _cost_of(cfg_p2, shape_name, mesh, 1, remat, rules)

    total = {
        k: c1[k] + (n_periods - 1) * (c2[k] - c1[k])
        for k in ("flops", "bytes", "coll")
    }
    # account for the real prologue (deepseek-v2's dense first layer ~ 1 period)
    if cfg.first_k_dense:
        total = {k: v + (c2[k] - c1[k]) for k, v in total.items()}

    # Chunked prefill wraps the layers in an n_chunks-trip scan that
    # HloCostAnalysis counts once — scale by the known trip count.
    if shape.kind == "prefill" and cfg.has_decode and shape.seq_len >= 8192:
        n_chunks = shape.seq_len // 4096
        total = {k: v * n_chunks for k, v in total.items()}

    if shape.kind == "decode":
        # Decode terms are computed ANALYTICALLY: the step reads the full
        # cache + the (bf16, fully sharded) weights exactly once per token,
        # which the HLO undercounts (the blockwise KV scan body is counted
        # once) and double-counts nothing.  This is the one shape where the
        # analytic model is exact rather than approximate.
        active_bytes = 2.0 * cfg.active_param_count() / CHIPS
        cache_bytes = _cache_bytes(cfg, shape) / CHIPS
        total["bytes"] = active_bytes + cache_bytes
        total["flops"] = model_flops(cfg, shape) / CHIPS

    peak_flops, hbm_bw, ici_bw = terms if terms is not None else (
        PEAK_FLOPS,
        HBM_BW,
        ICI_BW,
    )
    compute_t = total["flops"] / peak_flops
    memory_t = total["bytes"] / hbm_bw
    coll_t = total["coll"] / ici_bw
    bound = max(compute_t, memory_t, coll_t)
    dominant = (
        "compute"
        if bound == compute_t
        else ("memory" if bound == memory_t else "collective")
    )
    mf = model_flops(cfg, shape)
    hlo_flops_global = total["flops"] * CHIPS
    rec = {
        "arch": arch,
        "shape": shape_name,
        "status": "ok",
        "mesh": "16x16",
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "bound_s": bound,
        "roofline_frac": compute_t / bound if bound > 0 else 0.0,
        "hlo_flops_per_chip": total["flops"],
        "hlo_bytes_per_chip": total["bytes"],
        "coll_bytes_per_chip": total["coll"],
        "model_flops_global": mf,
        "useful_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "marginal_per_period": {k: c2[k] - c1[k] for k in ("flops", "bytes", "coll")},
    }
    # attach dry-run memory if available
    tag = f"{arch}__{shape_name}__single.json"
    path = os.path.join(_HERE, dryrun_dir, tag)
    if os.path.exists(path):
        with open(path) as f:
            dr = json.load(f)
        rec["dryrun_temp_bytes"] = dr.get("temp_size_in_bytes")
        rec["dryrun_arg_bytes"] = dr.get("argument_size_in_bytes")
    return rec


def render_table(records) -> str:
    head = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| useful FLOPs ratio | note |\n|---|---|---|---|---|---|---|---|"
    )
    rows = [head]
    for r in records:
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | {r['reason']} |"
            )
        else:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                f"roofline frac {r['roofline_frac']:.2f} |"
            )
    return "\n".join(rows)


def main() -> None:
    # device-count flag must be set before jax init — mirror dryrun.py
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )
    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--remat", default="full")
    ap.add_argument(
        "--machine",
        default=None,
        help="measured machine file for the roofline terms "
        "(default: results/perf/machine.json if present, else v5e preset)",
    )
    args = ap.parse_args()
    terms = resolve_terms(args.machine)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    records = []
    for arch in archs:
        for shape in shapes:
            path = os.path.join(args.out, f"{arch}__{shape}.json")
            if os.path.exists(path):
                with open(path) as f:
                    rec = json.load(f)
                records.append(rec)
                print(f"[cached] {arch} {shape}")
                continue
            try:
                rec = analyse_cell(
                    arch,
                    shape,
                    microbatches=args.microbatches,
                    remat=args.remat,
                    terms=terms,
                )
            except Exception as e:  # noqa: BLE001
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "status": "fail",
                    "error": f"{type(e).__name__}: {e}"[:1500],
                }
            records.append(rec)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            msg = (
                f"{rec.get('dominant', rec.get('reason', rec.get('error', '')))}"[:90]
            )
            print(f"[{rec['status']:4}] {arch:24} {shape:12} {msg}", flush=True)

    print()
    print(render_table(records))


if __name__ == "__main__":
    main()
