"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import json
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(spec: dict, timeout: int = 3600) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + ":" + _REPO
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks._worker", json.dumps(spec)],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=_REPO,
        env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    return json.loads(line[-1][len("RESULT_JSON:") :])


def save_results(name: str, records) -> str:
    out_dir = os.path.join(_REPO, "results", "benchmarks")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    return path
