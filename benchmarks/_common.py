"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import json
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(spec: dict, timeout: int = 3600) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + ":" + _REPO
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks._worker", json.dumps(spec)],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=_REPO,
        env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
    return json.loads(line[-1][len("RESULT_JSON:") :])


#: wall-clock date stamped into result files — set once by the runner
#: (``benchmarks/run.py``) so every module saved in one sweep carries the
#: same timestamp; stays None for ad-hoc single-module runs
RUN_DATE: str | None = None


def _git_sha() -> str | None:
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True,
            text=True,
            cwd=_REPO,
            timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if proc.returncode != 0:
        return None
    return proc.stdout.strip() or None


def collect_meta(date: str | None = None) -> dict:
    """Provenance header for a results file: who/what/where produced it."""
    meta = {
        "date": RUN_DATE if date is None else date,
        "git_sha": _git_sha(),
        "jax_version": None,
        "platform": None,
        "device_kind": None,
        "device_count": None,
    }
    try:
        import jax

        devices = jax.devices()
        meta["jax_version"] = jax.__version__
        meta["platform"] = devices[0].platform
        meta["device_kind"] = devices[0].device_kind
        meta["device_count"] = len(devices)
    except Exception:  # noqa: BLE001 — provenance must never fail a run
        pass
    return meta


def save_results(name: str, records, meta: dict | None = None) -> str:
    """Write ``{"meta": ..., "records": ...}`` to results/benchmarks/NAME.json.

    The meta header makes every perf number attributable: jax version,
    device kind/count, git SHA, and the sweep date the runner passed in.
    Extra ``meta`` keys from the caller override the collected defaults.
    """
    out_dir = os.path.join(_REPO, "results", "benchmarks")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    payload = {"meta": {**collect_meta(), **(meta or {})}, "records": records}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def save_bench_summary(metrics: dict, meta: dict | None = None) -> str:
    """Write the normalized cross-module summary the regression gate consumes.

    ``metrics`` maps a stable row name (the CSV ``name`` column) to its
    wall time in us/call.  The file lands at
    ``results/benchmarks/BENCH_summary.json`` with the same provenance
    header as :func:`save_results`; ``python -m repro.perf.regress``
    compares two of these and fails CI on > 1.3x slowdowns.
    """
    out_dir = os.path.join(_REPO, "results", "benchmarks")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_summary.json")
    payload = {
        "meta": {**collect_meta(), **(meta or {})},
        "metrics": {k: float(v) for k, v in sorted(metrics.items())},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
