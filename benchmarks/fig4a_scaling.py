"""Fig. 4a analogue: strong scaling of the round-robin policy (1,2,4,8
ranks).  The paper observes improvement to ~4 devices then flattening —
driven by synchronisation overhead and capped redistribution."""

from benchmarks._common import run_worker, save_results


def run(fast: bool = True):
    devs = (1, 2, 4) if fast else (1, 2, 4, 8, 12)
    grid = [("f2", 4, 1e-6)] if fast else [("f2", 6, 1e-7), ("f6", 6, 1e-7)]
    out = []
    for name, d, tol in grid:
        for n in devs:
            rec = run_worker(
                {
                    "n_devices": n,
                    "cases": [
                        dict(
                            integrand=name, d=d, rel_tol=tol,
                            capacity=1 << 14, max_iters=200,
                            distributed=n > 1,
                        )
                    ],
                },
            )[0]
            out.append({"integrand": name, "d": d, "n_devices": n, **rec})
    save_results("fig4a_scaling", out)
    return out


def rows(recs):
    base = {}
    for r in recs:
        key = (r["integrand"], r["d"])
        if r["n_devices"] == 1:
            base[key] = r["wall_s"]
    for r in recs:
        key = (r["integrand"], r["d"])
        speedup = base.get(key, r["wall_s"]) / max(r["wall_s"], 1e-9)
        yield (
            f"fig4a/{r['integrand']}_d{r['d']}_dev{r['n_devices']}",
            r["wall_s"] * 1e6,
            f"speedup={speedup:.2f};evals={r['n_evals']:.3g}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
