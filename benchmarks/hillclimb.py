"""Hillclimbing driver: sweep config variants for one (arch x shape) cell.

Per variant: full-module compile (memory + collectives) and, when requested,
the marginal-period roofline terms.  Results append to
results/hillclimb/<arch>__<shape>.json so iterations accumulate into the
§Perf log.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hillclimb --arch X --shape Y \
      --variant '{"name": "...", "microbatches": 8, "rules": {...}, "cfg": {...}}'
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os


def measure_variant(arch, shape_name, variant, *, roofline=True):
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    import jax

    from repro.configs import get_config
    from repro.launch.dryrun import build_cell, collective_bytes
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if variant.get("cfg"):
        cfg = dataclasses.replace(cfg, **variant["cfg"])
    mesh = make_production_mesh()
    kw = dict(
        microbatches=variant.get("microbatches", 8),
        remat=variant.get("remat", "full"),
        zero1=variant.get("zero1", False),
        rules=variant.get("rules"),
    )
    fn, args, sh, dn = build_cell(cfg, shape_name, mesh, **kw)
    compiled = jax.jit(fn, in_shardings=sh, donate_argnums=dn).lower(*args).compile()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {
        "variant": variant.get("name", "unnamed"),
        "spec": {k: v for k, v in variant.items() if k != "name"},
        "temp_gib": mem.temp_size_in_bytes / 2**30 if mem else None,
        "arg_gib": mem.argument_size_in_bytes / 2**30 if mem else None,
        "coll_gib": sum(v for k, v in coll.items() if k != "count") / 2**30,
        "coll_by_kind": coll,
    }
    if roofline:
        from benchmarks.roofline import analyse_cell

        rl = analyse_cell(
            arch,
            shape_name,
            microbatches=kw["microbatches"],
            remat=kw["remat"],
            rules=kw["rules"],
        )
        for key in (
            "compute_s",
            "memory_s",
            "collective_s",
            "dominant",
            "roofline_frac",
            "useful_ratio",
        ):
            rec[key] = rl.get(key)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, help="JSON variant spec")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    variant = json.loads(args.variant)
    rec = measure_variant(
        args.arch, args.shape, variant, roofline=not args.no_roofline
    )
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(rec)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
