"""Fig. 4b analogue: per-iteration work imbalance (idle-time proxy) with the
redistribution policy ON vs OFF, by device count.  idle ~ 1 - mean/max of
per-device work per iteration.

The ``mean_imbalance`` reported here is ``DistributedResult.mean_imbalance()``
— the same ``1 - mean/max`` statistic ``repro.telemetry.loadview`` derives
from a live run's recorded events (``mean_work_imbalance_from_events``), so
offline-benchmark and live-telemetry numbers are directly comparable
(equality on the same run is asserted in ``tests/test_telemetry.py``)."""

from benchmarks._common import run_worker, save_results


def run(fast: bool = True):
    devs = (2, 4) if fast else (2, 4, 8)
    grid = [("f6", 3, 1e-5)] if fast else [("f3", 6, 1e-8), ("f6", 6, 1e-8)]
    out = []
    for name, d, tol in grid:
        for n in devs:
            for redis in ("xor", "off"):
                rec = run_worker(
                    {
                        "n_devices": n,
                        "cases": [
                            dict(
                                integrand=name, d=d, rel_tol=tol,
                                capacity=1 << 13, max_iters=200,
                                redistribution=redis, distributed=True,
                            )
                        ],
                    },
                )[0]
                out.append(
                    {
                        "integrand": name,
                        "d": d,
                        "n_devices": n,
                        "redistribution": redis,
                        "mean_imbalance": rec["mean_imbalance"],
                        "status": rec["status"],
                        "wall_s": rec["wall_s"],
                    }
                )
    save_results("fig4b_idle", out)
    return out


def rows(recs):
    for r in recs:
        yield (
            f"fig4b/{r['integrand']}_d{r['d']}_dev{r['n_devices']}_{r['redistribution']}",
            r["wall_s"] * 1e6,
            f"imbalance={r['mean_imbalance']:.3f}",
        )


if __name__ == "__main__":
    for row in rows(run(fast=False)):
        print(",".join(str(x) for x in row))
