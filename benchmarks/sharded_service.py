"""Sharded batch-service scaling: one fleet, 1..N devices, same results.

The batch service's slot axis shards over a device mesh (each device owns
``batch_slots / n_devices`` slots and runs the vmapped windowed step
locally; convergence is decided from a psum of per-slot done masks once per
fused ``sync_every`` dispatch, and drained devices pull whole problems from
their cyclic ring partner).  This harness serves the *same* request fleet
through meshes of increasing size and reports problems/sec, speedup over the
single-device service, and the migration count — while asserting the
sharded runs return bit-identical integrals to the single-device run (the
service's parity guarantee).

Each mesh size runs in a subprocess so ``--xla_force_host_platform_device_count``
can size the virtual CPU mesh; on real multi-GPU/TPU hardware the same code
measures true scaling.  Virtual CPU devices share the same cores, so CPU
"speedups" mainly reflect dispatch/fusion overheads — the record that
matters here is the parity column and the harness itself.
"""

import json
import os
import subprocess
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _worker_main(spec: dict) -> None:
    n_dev = int(spec["n_devices"])
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={max(n_dev, 1)} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import QuadratureConfig
    from repro.core.integrands import get_param
    from repro.service import BatchScheduler, QuadRequest

    family = get_param("genz_gaussian")
    cfg = QuadratureConfig(
        d=spec["d"],
        integrand="genz_gaussian",
        rel_tol=spec["rel_tol"],
        capacity=spec["capacity"],
        batch_slots=spec["batch_slots"],
        max_iters=300,
        sync_every=spec.get("sync_every", 4),
        rebalance=spec.get("rebalance", "ring"),
    )
    rng = np.random.default_rng(spec["seed"])
    thetas = [family.sample_theta(cfg.d, rng) for _ in range(spec["n_requests"])]

    def fleet():
        return [QuadRequest(req_id=i, theta=t) for i, t in enumerate(thetas)]

    devices = jax.devices()[:n_dev]
    out = {}
    for label in ("cold", "warm"):  # cold pays every window-rung compile once
        sched = BatchScheduler(cfg, family, devices=devices)
        t0 = time.perf_counter()
        results = sorted(sched.serve(fleet()), key=lambda r: r.req_id)
        out[f"{label}_s"] = time.perf_counter() - t0
        out["stats"] = sched.last_stats
    out.update(
        n_devices=n_dev,
        statuses=sorted({r.status for r in results}),
        integrals=[r.integral.hex() for r in results],
        problems_per_s=spec["n_requests"] / out["warm_s"],
    )
    print("RESULT_JSON:" + json.dumps(out))


def run(fast: bool = True):
    import numpy as np  # noqa: F401  (parity of import environment)

    devs = (1, 2, 4) if fast else (1, 2, 4, 8)
    spec = dict(
        d=3,
        rel_tol=1e-6,
        capacity=1 << 11,
        batch_slots=16,
        n_requests=32 if fast else 64,
        seed=2026,
    )
    out = []
    ref_integrals = None
    for n in devs:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(_REPO, "src") + ":" + _REPO
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [
                sys.executable,
                "-m",
                "benchmarks.sharded_service",
                "--worker",
                json.dumps({**spec, "n_devices": n}),
            ],
            capture_output=True,
            text=True,
            timeout=3600,
            cwd=_REPO,
            env=env,
        )
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-3000:])
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON:")]
        rec = json.loads(line[-1][len("RESULT_JSON:") :])
        if ref_integrals is None:
            ref_integrals = rec["integrals"]
        parity = rec["integrals"] == ref_integrals
        assert parity, f"sharded service diverged from 1-device run at n={n}"
        rec.pop("integrals")
        out.append(
            {
                **{k: v for k, v in spec.items() if k != "seed"},
                **rec,
                "bit_parity_vs_1dev": parity,
            }
        )
        from benchmarks._common import save_results

        save_results("sharded_service", out)  # incremental: keep partial runs
    return out


def rows(recs):
    base = next((r["warm_s"] for r in recs if r["n_devices"] == 1), None)
    for r in recs:
        speedup = (base or r["warm_s"]) / max(r["warm_s"], 1e-9)
        yield (
            f"sharded_service/dev{r['n_devices']}_slots{r['batch_slots']}",
            r["warm_s"] / r["n_requests"] * 1e6,
            f"problems_per_s={r['problems_per_s']:.2f};speedup={speedup:.2f};"
            f"migrations={r['stats']['migrations']};"
            f"parity={r['bit_parity_vs_1dev']}",
        )


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--worker":
        _worker_main(json.loads(sys.argv[2]))
    else:
        for row in rows(run(fast="--full" not in sys.argv)):
            print(",".join(str(x) for x in row))
