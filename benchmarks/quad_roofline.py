"""Quadrature roofline benchmark: measured machine + kernel cost catalog.

The harness adapter for :mod:`repro.perf` — profiles this device
(``repro.perf.machine``), lowers and times the real compiled quadrature
programs (``repro.perf.catalog``: GM eval rungs, windowed advance, VEGAS
iterate, fused service dispatch), and reports each kernel's wall time with
its predicted-vs-measured roofline fraction as the ``derived`` column.

Side effects: refreshes ``results/perf/machine.json`` and
``results/perf/kernel_catalog.json`` (the report's inputs) and saves a
provenance-headed ``results/benchmarks/quad_roofline.json``.

Unlike the retired LM sweep in :mod:`benchmarks.roofline` this costs the
programs this repo actually runs, on terms measured on this machine —
``python -m benchmarks.run --roofline`` routes here.
"""

from __future__ import annotations

from benchmarks._common import save_results
from repro.perf import catalog as catalog_lib
from repro.perf import machine as machine_lib


def run(fast: bool = True) -> list[dict]:
    machine = machine_lib.profile_machine(fast=fast)
    machine_lib.save_machine(machine, machine_lib.DEFAULT_PATH)
    catalog = catalog_lib.build_catalog(machine, fast=fast)
    catalog_lib.save_catalog(catalog, catalog_lib.DEFAULT_PATH)
    entries = catalog["entries"]
    save_results(
        "quad_roofline",
        entries,
        meta={"machine": machine["name"], "fast": fast},
    )
    return entries


def rows(recs: list[dict]):
    for e in recs:
        rung = e.get("rung")
        name = f"roofline_{e['kernel']}" + (f"_n{rung}" if rung else "")
        yield (name, f"{e['measured_s'] * 1e6:.1f}", f"{e['roofline_frac']:.3f}")


if __name__ == "__main__":
    for row in rows(run(fast=True)):
        print(",".join(str(x) for x in row))
